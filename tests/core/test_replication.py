"""Async geo-replication (ISSUE 3 tentpole): log/cursor/replay protocol.

The properties under test are the ones the protocol's safety rests on:

  * ``merge_reduced`` replays of shipped batches rebuild byte-identical
    store state — including under re-delivery and out-of-order delivery
    (Algorithm-2 latest-wins is an idempotent commutative join);
  * the log's cursors never under-report lag (out-of-order acks advance
    only the contiguous prefix) and truncation never drops un-acked
    batches (backpressure raises instead);
  * the router serves local reads from in-sync replicas only, and
    ``failover`` replays the promoted replica's un-acked suffix so its
    store matches the home store's pre-failure state exactly;
  * geo-fenced home regions refuse replication (§4.1.2 compliance).
"""

import numpy as np
import pytest

from repro.core.assets import (
    Entity,
    Feature,
    FeatureSetSpec,
    MaterializationSettings,
)
from repro.core.dsl import DslTransform, RollingAgg, UDFTransform
from repro.core.offline_store import CREATION_TS, EVENT_TS, OfflineStore
from repro.core.online_store import OnlineStore
from repro.core.regions import ComplianceError, GeoTopology, Region, RegionDownError
from repro.core.replication import (
    GeoFeatureStore,
    PlaneLag,
    ReplicationLog,
    ReplicationLogFull,
)
from repro.core.table import Table
from repro.data.sources import SyntheticEventSource

HOUR = 3_600_000


def make_spec(n_feats=2):
    return FeatureSetSpec(
        name="fs",
        version=1,
        entity=Entity("cust", ("entity_id",)),
        features=tuple(Feature(f"f{i}") for i in range(n_feats)),
        source_name="src",
        transform=UDFTransform(lambda df, ctx: df, name="id"),
        materialization=MaterializationSettings(True, True),
    )


def make_frame(rng, n, id_hi, ev_hi, n_feats=2):
    cols = {
        "entity_id": rng.integers(0, id_hi, n).astype(np.int64),
        "ts": rng.integers(0, ev_hi, n).astype(np.int64),
    }
    for i in range(n_feats):
        cols[f"f{i}"] = rng.random(n).astype(np.float32)
    return Table(cols)


def assert_dumps_identical(a: OnlineStore, b: OnlineStore, spec, ctx=""):
    da, db = a.dump_all(spec.name, spec.version), b.dump_all(spec.name, spec.version)
    assert set(da.names) == set(db.names), ctx
    for name in da.names:
        np.testing.assert_array_equal(da[name], db[name], err_msg=f"{ctx}: {name}")


def assert_offline_identical(a: OfflineStore, b: OfflineStore, spec, ctx=""):
    """Chunk-set equivalence: same full-key set and values, independent of
    chunk boundaries (canonical_history sorts by the full record key)."""
    da = a.canonical_history(spec.name, spec.version)
    db = b.canonical_history(spec.name, spec.version)
    assert set(da.names) == set(db.names), ctx
    assert len(da) == len(db), f"{ctx}: {len(da)} vs {len(db)} rows"
    for name in da.names:
        np.testing.assert_array_equal(da[name], db[name], err_msg=f"{ctx}: {name}")


def assert_planes_identical(g: GeoFeatureStore, region: str, spec, ctx=""):
    assert_dumps_identical(
        g.fs.online, g.replicator.stores[region], spec, f"{ctx} [online]"
    )
    assert_offline_identical(
        g.fs.offline, g.replicator.offline_stores[region], spec, f"{ctx} [offline]"
    )


def topo(fenced_home=False):
    return GeoTopology(
        regions={
            "home": Region("home", geo_fenced=fenced_home),
            "near": Region("near"),
            "far": Region("far"),
        },
        local_latency_ms=1.0,
        cross_region_latency_ms=60.0,
        link_latency_ms={("home", "near"): 30.0, ("home", "far"): 90.0},
    )


def geo_store(**kw):
    kw.setdefault("topology", topo())
    kw.setdefault("home_region", "home")
    g = GeoFeatureStore("geo", **kw)
    g.register_source(SyntheticEventSource("tx", num_entities=40))
    g.create_feature_set(
        FeatureSetSpec(
            name="act",
            version=1,
            entity=Entity("customer", ("entity_id",)),
            features=(Feature("s2", "float32"),),
            source_name="tx",
            transform=DslTransform(
                "entity_id", "ts", [RollingAgg("s2", "amount", 2 * HOUR, "sum")]
            ),
            timestamp_col="ts",
            source_lookback=2 * HOUR,
            materialization=MaterializationSettings(
                offline_enabled=True, online_enabled=True, schedule_interval=HOUR
            ),
        )
    )
    return g


# -- merge stats carry the reduced batch --------------------------------------


@pytest.mark.parametrize("engine", ["loop", "vector", "kernel"])
def test_merge_stats_reduced_rows_match_store_state(engine):
    """touched_* arrays must be exactly the rows the merge wrote: replaying
    them alone into a fresh store rebuilds identical state."""
    spec = make_spec()
    src = OnlineStore(num_partitions=4, merge_engine=engine)
    dst = OnlineStore(num_partitions=4, merge_engine=engine)
    rng = np.random.default_rng(0)
    for i in range(4):
        stats = src.merge(spec, make_frame(rng, 80, 30, 50 * (i + 1)), 1_000 + i)
        assert stats["creation_ts"] == 1_000 + i
        # touched_* are per-SLOT (one winner per unique id); the tallies are
        # per-ROW, so duplicates make them an upper bound
        n_touched = len(stats["touched_parts"])
        assert n_touched <= stats["inserts"] + stats["overrides"]
        assert len(stats["touched_keys"]) == n_touched
        assert len(stats["touched_event_ts"]) == n_touched
        assert stats["touched_values"].shape == (n_touched, 2)
        dst.merge_reduced(
            spec,
            stats["touched_keys"],
            stats["touched_event_ts"],
            stats["touched_values"],
            stats["creation_ts"],
        )
    assert_dumps_identical(src, dst, spec, f"reduced replay ({engine})")


@pytest.mark.parametrize("engine", ["loop", "vector", "kernel"])
def test_replay_idempotent_and_order_independent(engine):
    """Re-delivered and reordered reduced batches converge to the state a
    fresh in-order rebuild produces — the property failover replay rests
    on."""
    spec = make_spec()
    home = OnlineStore(num_partitions=4)
    rng = np.random.default_rng(1)
    batches = []
    for i in range(6):
        stats = home.merge(spec, make_frame(rng, 60, 25, 40 * (i + 1)), 2_000 + i)
        batches.append(stats)
    fresh = OnlineStore(num_partitions=4, merge_engine=engine)
    for s in batches:
        fresh.merge_reduced(
            spec,
            s["touched_keys"],
            s["touched_event_ts"],
            s["touched_values"],
            s["creation_ts"],
        )
    chaotic = OnlineStore(num_partitions=4, merge_engine=engine)
    order = [3, 0, 5, 1, 4, 2, 3, 0, 5, 1, 4, 2, 2]  # shuffled + re-delivered
    for i in order:
        s = batches[i]
        chaotic.merge_reduced(
            spec,
            s["touched_keys"],
            s["touched_event_ts"],
            s["touched_values"],
            s["creation_ts"],
        )
    assert_dumps_identical(home, fresh, spec, "fresh rebuild")
    assert_dumps_identical(fresh, chaotic, spec, f"chaotic replay ({engine})")


# -- log: cursors, out-of-order acks, truncation safety -----------------------


def _log_batch(log, seq_hint=0):
    return log.append(
        ("fs", 1),
        1_000 + seq_hint,
        np.arange(3, dtype=np.int64),
        np.arange(3, dtype=np.int64),
        np.zeros((3, 1), np.float32),
    )


def test_log_lag_under_out_of_order_acks():
    log = ReplicationLog()
    log.register_replica("r")
    for i in range(4):
        _log_batch(log, i)
    lag = log.lag("r")
    assert (lag.batches, lag.rows, lag.oldest_pending_creation_ts) == (4, 12, 1_000)
    assert lag.planes == {
        "online": PlaneLag(batches=4, rows=12),
        "offline": PlaneLag(),
    }
    log.ack("r", 2)  # out of order: cursor must NOT advance
    assert log.cursors["r"] == 0
    assert log.lag("r").batches == 3
    assert [b.seq for b in log.pending("r")] == [0, 1, 3]
    log.ack("r", 0)  # contiguous prefix {0} + ahead {2}: cursor -> 1
    assert log.cursors["r"] == 1
    log.ack("r", 1)  # closes the gap: cursor jumps over the acked 2
    assert log.cursors["r"] == 3
    lag = log.lag("r")
    assert (lag.batches, lag.rows, lag.oldest_pending_creation_ts) == (1, 3, 1_003)
    assert lag.planes == {
        "online": PlaneLag(batches=1, rows=3),
        "offline": PlaneLag(),
    }
    log.ack("r", 3)
    assert log.lag("r").batches == 0
    # re-acking below the cursor is a harmless no-op (re-delivery)
    log.ack("r", 1)
    assert log.cursors["r"] == 4


def test_log_truncation_never_drops_unacked():
    log = ReplicationLog(capacity=4)
    log.register_replica("fast")
    log.register_replica("slow")
    for i in range(4):
        _log_batch(log, i)
    for i in range(4):
        log.ack("fast", i)
    assert log.truncate() == 0  # slow still holds the whole window
    assert [b.seq for b in log.pending("slow")] == [0, 1, 2, 3]
    with pytest.raises(ReplicationLogFull):
        _log_batch(log, 4)  # backpressure, not data loss
    assert [b.seq for b in log.pending("slow")] == [0, 1, 2, 3]
    log.ack("slow", 0)
    log.ack("slow", 1)
    _log_batch(log, 4)  # append now truncates exactly the acked prefix
    assert [b.seq for b in log.pending("slow")] == [2, 3, 4]
    assert [b.seq for b in log.pending("fast")] == [4]


def test_log_append_copies_and_freezes_publisher_buffers():
    """Regression (ISSUE 5 satellite): ``append`` used to wrap the caller's
    live arrays with no copy, so a publisher mutating its buffers after
    publish (in-place slot update, offline chunk compaction) corrupted any
    un-shipped batch.  The log must hold frozen private copies."""
    log = ReplicationLog()
    log.register_replica("r")
    keys = np.arange(4, dtype=np.int64)
    event_ts = np.arange(4, dtype=np.int64)
    values = np.ones((4, 2), np.float32)
    cols = {"entity_id": np.arange(4, dtype=np.int64)}
    online = log.append(("fs", 1), 1_000, keys, event_ts, values)
    offline = log.append(
        ("fs", 1),
        1_001,
        keys,
        event_ts,
        np.empty((4, 0), np.float32),
        plane="offline",
        columns=cols,
    )
    # publisher scribbles over every buffer it handed in
    keys[:] = -7
    event_ts[:] = -7
    values[:] = np.nan
    cols["entity_id"][:] = -7
    np.testing.assert_array_equal(online.keys, np.arange(4))
    np.testing.assert_array_equal(online.event_ts, np.arange(4))
    np.testing.assert_array_equal(online.values, np.ones((4, 2), np.float32))
    np.testing.assert_array_equal(offline.columns["entity_id"], np.arange(4))
    # and nothing downstream can mutate a logged batch in place either
    for a in (online.keys, online.values, offline.columns["entity_id"]):
        assert not a.flags.writeable
        with pytest.raises((ValueError, RuntimeError)):
            a[0] = 1


def test_mutate_after_publish_does_not_corrupt_replica():
    """End-to-end form of the same regression: corrupt the merge stats
    arrays AFTER the listener published them, then drain — the replica must
    still converge to the home store's true state on both planes."""
    spec = make_spec()
    rng = np.random.default_rng(9)
    home = OnlineStore(num_partitions=4)
    home_off = OfflineStore(num_shards=4)
    published = []
    from repro.core.replication import GeoReplicator

    topo2 = GeoTopology(regions={"h": Region("h"), "r": Region("r")})
    repl = GeoReplicator(home, topology=topo2, home_region="h", home_offline=home_off)
    home.merge_listeners.append(lambda s, st: published.append(st))
    home_off.merge_listeners.append(lambda s, st: published.append(st))
    replica, replica_off = OnlineStore(num_partitions=4), OfflineStore(num_shards=4)
    repl.add_replica("r", replica, replica_off)
    for i in range(3):
        frame = make_frame(rng, 50, 20, 40 * (i + 1))
        home.merge(spec, frame, 3_000 + i)
        home_off.merge(spec, frame, 4_000 + i)
    for st in published:  # the publisher's buffers go bad after the fact
        for key in ("touched_values", "touched_keys", "inserted_keys"):
            if key in st:
                st[key][:] = -1
        for col in st.get("inserted_columns", {}).values():
            col[:] = -1
    repl.drain()
    assert_dumps_identical(home, replica, spec, "mutate-after-publish")
    assert_offline_identical(home_off, replica_off, spec, "mutate-after-publish")


def test_drain_encodes_shared_runs_once_for_aligned_replicas(monkeypatch):
    """Replicas whose cursors align receive the SAME encoded frame: the
    zlib pass over a pending run happens once per drain, not once per
    replica (logged batches are immutable, so the encoding is pure)."""
    from repro.core import wire
    from repro.core.replication import GeoReplicator

    spec = make_spec()
    topo2 = GeoTopology(
        regions={"h": Region("h"), "r1": Region("r1"), "r2": Region("r2")}
    )
    home = OnlineStore(num_partitions=4)
    repl = GeoReplicator(home, topology=topo2, home_region="h")
    a, b = OnlineStore(num_partitions=4), OnlineStore(num_partitions=4)
    repl.add_replica("r1", a)
    repl.add_replica("r2", b)
    rng = np.random.default_rng(17)
    for i in range(3):
        home.merge(spec, make_frame(rng, 40, 20, 30 * (i + 1)), 6_000 + i)
    calls = []
    real = wire.encode_run
    monkeypatch.setattr(
        wire, "encode_run", lambda *a_, **kw: (calls.append(1), real(*a_, **kw))[1]
    )
    repl.drain()
    assert len(calls) == 1  # one coalesced run, two replicas, one encode
    assert_dumps_identical(home, a, spec, "r1")
    assert_dumps_identical(home, b, spec, "r2")
    assert repl.shipped["r1"].bytes == repl.shipped["r2"].bytes


def test_register_replica_rejects_out_of_range_cursor():
    """Regression (ISSUE 5 satellite): a cursor past the head (or negative)
    made ``pending_count`` negative, which silently passed the in-sync read
    gate for an arbitrarily stale replica."""
    log = ReplicationLog()
    for i in range(3):
        _log_batch(log, i)
    with pytest.raises(ValueError, match="from_seq"):
        log.register_replica("r", from_seq=-1)
    with pytest.raises(ValueError, match="from_seq"):
        log.register_replica("r", from_seq=4)  # past next_seq=3
    assert "r" not in log.cursors  # nothing half-registered
    assert log.register_replica("zero", from_seq=0) == 0
    assert log.pending_count("zero") == 3
    assert log.register_replica("head", from_seq=3) == 3
    assert log.pending_count("head") == 0
    # a cursor below the TRUNCATED floor pins batches that no longer exist:
    # pending_count would stay positive forever with nothing drainable
    trunc = ReplicationLog()
    trunc.register_replica("a")
    for i in range(3):
        _log_batch(trunc, i)
    for i in range(3):
        trunc.ack("a", i)
    assert trunc.truncate() == 3
    with pytest.raises(ValueError, match="from_seq"):
        trunc.register_replica("b", from_seq=0)
    assert trunc.register_replica("b", from_seq=3) == 3  # head still fine


def test_log_unregistered_replica_truncates_everything():
    log = ReplicationLog(capacity=2)
    _log_batch(log, 0)
    _log_batch(log, 1)
    _log_batch(log, 2)  # no cursors: acked-by-all is vacuously true
    assert len(log) <= 2


def _offline_log_batch(log, seq_hint=0, rows=2):
    return log.append(
        ("fs", 1),
        2_000 + seq_hint,
        np.arange(rows, dtype=np.int64),
        np.arange(rows, dtype=np.int64),
        np.empty((rows, 0), np.float32),
        plane="offline",
        columns={"entity_id": np.arange(rows, dtype=np.int64)},
    )


def test_log_mixed_plane_truncation_counts_both_planes():
    """Regression (ISSUE 4 satellite): an un-acked OFFLINE batch pins the
    tail exactly like an online one — truncation accounting and the
    per-plane lag breakdown must see both planes."""
    log = ReplicationLog(capacity=2)
    log.register_replica("r")
    _offline_log_batch(log, 0)
    _log_batch(log, 1)
    log.ack("r", 1)  # online half acked (out of order); offline still pins
    with pytest.raises(ReplicationLogFull):
        _log_batch(log, 2)
    assert [b.plane for b in log.pending("r")] == ["offline"]
    lag = log.lag("r")
    assert lag.planes == {
        "online": PlaneLag(),
        "offline": PlaneLag(batches=1, rows=2),
    }
    log.ack("r", 0)  # both planes acked -> append truncates the prefix
    _log_batch(log, 2)
    assert [b.seq for b in log.pending("r")] == [2]
    assert log.lag("r").offline == PlaneLag()


# -- geo feature store: routing, lag gating, compliance -----------------------


def test_geo_fenced_home_refuses_replication():
    g = GeoFeatureStore("geo", topology=topo(fenced_home=True), home_region="home")
    with pytest.raises(ComplianceError):
        g.add_replica("near")


def test_reads_gate_on_replication_lag():
    g = geo_store(replica_regions=("near",))
    g.tick(now=2 * HOUR)
    ids = [np.arange(10, dtype=np.int64)]
    # replica lags: reads from 'near' must fall back to home (WAN latency)
    assert g.lag("near").batches > 0
    _, _, route = g.get_online_features("act", 1, ids, consumer_region="near")
    assert route == {"region": "home", "modeled_ms": 30.0}
    # relaxing the staleness bound lets the lagging replica serve locally
    _, _, relaxed = g.get_online_features(
        "act", 1, ids, consumer_region="near", max_lag_batches=10
    )
    assert relaxed["region"] == "near"
    g.drain()
    vals_home, found_home, _ = g.get_online_features("act", 1, ids)
    vals, found, route = g.get_online_features("act", 1, ids, consumer_region="near")
    assert route == {"region": "near", "modeled_ms": 1.0}  # local read
    np.testing.assert_array_equal(found, found_home)
    np.testing.assert_array_equal(vals, vals_home)


def test_lag_metrics_surface_in_monitor():
    g = geo_store(replica_regions=("near",))
    g.tick(now=2 * HOUR)
    gauges = g.fs.monitor.system.snapshot()["gauges"]
    assert gauges["replication/lag_batches/near"] > 0
    g.drain()
    g.tick(now=3 * HOUR)  # another materialization window re-lags the replica
    gauges = g.fs.monitor.system.snapshot()["gauges"]
    assert gauges["replication/lag_batches/near"] > 0
    g.drain()
    g.fs._refresh_staleness()
    gauges = g.fs.monitor.system.snapshot()["gauges"]
    assert gauges["replication/lag_batches/near"] == 0
    assert gauges["replication/staleness_ms/near"] == 0
    assert g.fs.monitor.system.counters["replication/shipped_batches"] > 0


def test_snapshot_bootstrap_of_late_replica():
    g = geo_store()
    g.tick(now=3 * HOUR)  # home has state before any replica exists
    g.add_replica("near", chunk_rows=16)  # bounded delta chunks, not one dump
    spec = g.registry.get_feature_set("act", 1)
    assert g.lag("near").batches == 0  # snapshot cut at head, not replay
    assert g.last_bootstrap["online_rows"] > 0
    assert g.last_bootstrap["offline_rows"] > 0
    assert g.last_bootstrap["chunks"] > 2  # actually streamed in pieces
    assert_planes_identical(g, "near", spec, "delta bootstrap")


def test_materializer_outcomes_carry_replication_seq():
    g = geo_store(replica_regions=("near",))
    g.tick(now=HOUR)
    outcomes = g.fs.materializer.outcomes
    seqs = [o.online_stats["replication_seq"] for o in outcomes]
    assert seqs == sorted(seqs)
    assert all(s is not None for s in seqs)
    off_seqs = [o.offline_stats["replication_seq"] for o in outcomes]
    assert all(s is not None for s in off_seqs)
    # the paper's fixed merge order: each job's offline batch precedes its
    # online batch in the one shared log sequence
    assert all(off < on for off, on in zip(off_seqs, seqs))


def test_publisher_backpressure_degrades_to_sync_drain():
    """A full log must never lose a batch the home store already applied:
    the publisher drains healthy replicas synchronously and keeps going."""
    g = geo_store(replica_regions=("near",), log_capacity=2)
    for h in range(2, 12, 2):
        g.tick(now=h * HOUR)  # many more batches than the log holds
    assert g.fs.monitor.system.counters.get("replication/log_force_appends", 0) == 0
    g.drain()
    assert_dumps_identical(
        g.fs.online,
        g.replicator.stores["near"],
        g.registry.get_feature_set("act", 1),
        "backpressure sync-drain",
    )


def test_publisher_force_appends_when_dead_replica_pins_log():
    """An unhealthy replica can't be drained; the log grows past capacity
    (with a monitor counter) instead of dropping batches, and the replica
    converges byte-identically once it recovers."""
    g = geo_store(replica_regions=("near", "far"), log_capacity=2)
    g.mark_down("far")
    for h in range(2, 12, 2):
        g.tick(now=h * HOUR)
    assert len(g.log) > 2  # grew past capacity rather than dropping
    assert g.fs.monitor.system.counters["replication/log_force_appends"] > 0
    spec = g.registry.get_feature_set("act", 1)
    # the sync-drain fallback kept the healthy replica within one
    # append-window of home; an explicit drain closes the tail
    assert g.lag("near").batches <= len(g.log)
    g.drain("near")
    assert_dumps_identical(
        g.fs.online, g.replicator.stores["near"], spec, "healthy replica"
    )
    g.mark_up("far")
    g.drain("far")
    assert_dumps_identical(
        g.fs.online, g.replicator.stores["far"], spec, "recovered replica"
    )
    assert len(g.log) <= 2  # drained cursors let truncation shrink it back


def test_second_failover_skips_the_dead_ex_home():
    """After promotion the ex-home has no store; a later failover must pick
    a real replica, and the ex-home can rejoin via snapshot bootstrap."""
    g = geo_store(replica_regions=("near", "far"))
    spec = g.registry.get_feature_set("act", 1)
    ids = [np.arange(40, dtype=np.int64)]
    g.tick(now=2 * HOUR)
    g.mark_down("home")
    assert g.failover()["promoted"] == "near"
    assert "home" not in g.placement.replicas
    g.mark_up("home")  # region recovers, but its store is gone
    g.mark_down("near")
    info = g.failover()
    assert info["promoted"] == "far"  # not the storeless ex-home
    assert g.home_region == "far" and g.placement.home_region == "far"
    g.tick(now=4 * HOUR)
    vals, found, route = g.get_online_features("act", 1, ids, consumer_region="far")
    assert route == {"region": "far", "modeled_ms": 1.0}
    # the recovered ex-home rejoins as a replica via snapshot bootstrap
    g.add_replica("home")
    g.drain()
    assert_dumps_identical(
        g.fs.online, g.replicator.stores["home"], spec, "ex-home rejoin"
    )
    _, _, route = g.get_online_features("act", 1, ids, consumer_region="home")
    assert route == {"region": "home", "modeled_ms": 1.0}


# -- offline plane: ship, delta bootstrap, rejoin (ISSUE 4) -------------------


def test_offline_plane_replicates_on_drain():
    g = geo_store(replica_regions=("near",))
    g.tick(now=2 * HOUR)
    spec = g.registry.get_feature_set("act", 1)
    lag = g.lag("near")
    assert lag.offline.batches > 0  # offline batches ship too
    assert lag.online.batches > 0
    gauges = g.fs.monitor.system.snapshot()["gauges"]
    assert gauges["replication/lag_batches/offline/near"] > 0
    g.drain()
    assert_planes_identical(g, "near", spec, "post-drain")
    counters = g.fs.monitor.system.counters
    assert counters["replication/shipped_bytes/offline"] > 0
    assert counters["replication/shipped_bytes/online"] > 0


@pytest.mark.parametrize("engine", ["loop", "vector"])
def test_offline_shipped_batches_rebuild_identical_history(engine):
    """The inserted-rows stats a home offline merge reports are exactly the
    shipping unit: applying them alone (re-delivered, even) rebuilds a
    chunk-set-identical replica."""
    spec = make_spec()
    rng = np.random.default_rng(5)
    home = OfflineStore(num_shards=4, merge_engine=engine)
    shipped = []
    home.merge_listeners.append(lambda s, st: shipped.append(st))
    for i in range(5):
        # overlapping frames so later merges hit the full-key dedup path
        home.merge(spec, make_frame(rng, 60, 25, 40 * (i + 1)), 10**6 + i)
        home.merge(spec, make_frame(rng, 30, 25, 40 * (i + 1)), 10**6 + 100 + i)
    assert sum(st["inserted"] for st in shipped) == home.num_rows("fs", 1)
    replica = OfflineStore(num_shards=4)
    for st in shipped + shipped:  # at-least-once delivery: ship every batch twice
        out = replica.apply_chunks(
            spec,
            st["inserted_keys"],
            st["inserted_event_ts"],
            st["creation_ts"],
            st["inserted_columns"],
        )
        assert out["applied"] <= st["inserted"]
    assert_offline_identical(home, replica, spec, f"reduced replay ({engine})")


def test_online_only_replica_rejected_when_home_publishes_offline():
    """A replica without an offline store would crash the first offline
    drain (and, via the backpressure fallback, the home write path) — the
    replicator must reject it up front."""
    g = geo_store()
    with pytest.raises(ValueError, match="offline store"):
        g.replicator.add_replica("near", OnlineStore())


def test_offline_replica_rejected_when_home_is_online_only():
    """The mirror-image misconfiguration: an offline-capable replica under
    an online-only home becomes the crash once promote() makes IT the
    publisher — the replica set must stay plane-homogeneous."""
    from repro.core.replication import GeoReplicator

    rep = GeoReplicator(OnlineStore(), topology=topo(), home_region="home")
    with pytest.raises(ValueError, match="offline"):
        rep.add_replica("near", OnlineStore(), OfflineStore())
    rep.add_replica("near", OnlineStore())  # online-only set stays fine


def test_delta_bootstrap_interrupted_and_retried_is_idempotent():
    """A bootstrap stream that dies mid-way and is retried from scratch must
    not duplicate offline chunks or disturb online latest-wins."""
    g = geo_store()
    g.tick(now=4 * HOUR)
    spec = g.registry.get_feature_set("act", 1)
    g.placement.add_replica("near")
    store = OnlineStore(num_partitions=g.fs.online.num_partitions)
    offline = OfflineStore(num_shards=g.fs.offline.num_shards)
    rep = g.replicator
    rep.add_replica("near", store, offline)
    # interrupted stream: only a prefix of the offline chunks lands
    chunks = list(g.fs.offline.export_chunks("act", 1, max_rows=16))
    assert len(chunks) > 2
    offline.register(spec)
    for chunk in chunks[: len(chunks) // 2]:
        cols = {
            k: chunk[k]
            for k in chunk.names
            if k not in ("__key__", EVENT_TS, CREATION_TS)
        }
        offline.apply_chunks(
            spec, chunk["__key__"], chunk[EVENT_TS], chunk[CREATION_TS], cols
        )
    partial = offline.num_rows("act", 1)
    assert 0 < partial < g.fs.offline.num_rows("act", 1)
    # retry = full re-stream; overlap with the partial prefix is a no-op
    rep.bootstrap_delta("near", spec, chunk_rows=16)
    assert_offline_identical(g.fs.offline, offline, spec, "retried bootstrap")
    assert_dumps_identical(g.fs.online, store, spec, "retried bootstrap [online]")
    # a second full retry inserts nothing (no duplicate chunks)
    before = offline.num_rows("act", 1)
    out = rep.bootstrap_delta("near", spec, chunk_rows=16)
    assert offline.num_rows("act", 1) == before
    assert out["offline_rows"] == before  # streamed again, all deduped
    assert g.lag("near").batches == 0


def test_rejoin_after_failover_converges_both_planes():
    """The recovered ex-home rejoins via the delta-bootstrap path and
    becomes a first-class replica of BOTH planes again."""
    g = geo_store(replica_regions=("near", "far"))
    spec = g.registry.get_feature_set("act", 1)
    g.tick(now=2 * HOUR)  # leaves an un-drained suffix
    g.mark_down("home")
    assert g.failover()["promoted"] == "near"
    g.tick(now=4 * HOUR)  # the new primary keeps materializing
    with pytest.raises(RegionDownError):
        g.rejoin("home")  # still down: must mark_up first
    g.mark_up("home")
    info = g.rejoin("home")
    assert info["rejoined"] == "home"
    assert info["online_rows"] > 0 and info["offline_rows"] > 0
    g.drain()
    assert_planes_identical(g, "home", spec, "rejoined ex-home")
    # and it keeps receiving new batches like any replica
    g.tick(now=6 * HOUR)
    g.drain()
    assert_planes_identical(g, "home", spec, "rejoined steady-state")
    ids = [np.arange(40, dtype=np.int64)]
    _, _, route = g.get_online_features("act", 1, ids, consumer_region="home")
    assert route == {"region": "home", "modeled_ms": 1.0}  # serving locally
    with pytest.raises(ValueError):
        g.rejoin("near")  # already in the serving set


def test_mixed_plane_backpressure_counts_both_planes():
    """Regression (ISSUE 4 satellite): with a tiny log, every job's offline
    AND online batches hit backpressure; the sync-drain fallback must drain
    both planes of the healthy replica — if it skipped one, the cursor
    would never free the prefix and force-appends would fire."""
    g = geo_store(replica_regions=("near",), log_capacity=1)
    for h in range(2, 10, 2):
        g.tick(now=h * HOUR)
    assert g.fs.monitor.system.counters.get("replication/log_force_appends", 0) == 0
    spec = g.registry.get_feature_set("act", 1)
    g.drain()
    assert_planes_identical(g, "near", spec, "mixed-plane backpressure")
    assert len(g.log) <= 1


# -- the two-region end-to-end scenario (acceptance) --------------------------


def test_two_region_scenario_with_failover_replay():
    """Materialize at home; drain; serve identical rows locally from the
    replica; keep materializing WITHOUT draining (un-acked suffix); kill
    home; failover replays the suffix on BOTH planes — the promoted online
    store's dump_all is byte-identical and its offline store chunk-set-
    identical to the lost home — then the recovered ex-home rejoins and
    converges on both planes."""
    g = geo_store(replica_regions=("near", "far"))
    spec = g.registry.get_feature_set("act", 1)
    ids = [np.arange(40, dtype=np.int64)]

    g.tick(now=3 * HOUR)
    g.drain()
    vals_home, found_home, route_home = g.get_online_features(
        "act", 1, ids, consumer_region="home"
    )
    vals_rep, found_rep, route_rep = g.get_online_features(
        "act", 1, ids, consumer_region="near"
    )
    assert route_home == {"region": "home", "modeled_ms": 1.0}
    assert route_rep == {"region": "near", "modeled_ms": 1.0}  # local read
    np.testing.assert_array_equal(found_rep, found_home)
    np.testing.assert_array_equal(vals_rep, vals_home)

    # more materialization the replicas have NOT applied yet
    g.tick(now=6 * HOUR)
    assert g.lag("near").batches > 0
    assert g.lag("near").offline.batches > 0
    pre_failure = g.fs.online.dump_all("act", 1)
    pre_failure_off = g.fs.offline.canonical_history("act", 1)

    # the lagging replicas have live lag gauges going into the failover
    gauges = g.fs.monitor.system.snapshot()["gauges"]
    assert gauges["replication/lag_batches/near"] > 0
    assert gauges["replication/lag_batches/offline/near"] > 0

    g.mark_down("home")
    with pytest.raises(RegionDownError):
        g.route_read("home")  # nothing in sync while replicas lag
    info = g.failover()
    assert info["promoted"] == "near"  # nearest healthy, not set order
    assert info["replayed_batches"] > 0

    # membership changed: the promoted region is home now (in sync by
    # definition) and the dead ex-home left the serving set — neither may
    # keep reporting its last per-replica lag/staleness (ISSUE 5 satellite)
    gauges = g.fs.monitor.system.snapshot()["gauges"]
    for region in ("near", "home"):
        assert not any(
            k.startswith("replication/") and k.endswith(f"/{region}")
            for k in gauges
        ), f"stale replication gauges for {region}"
    assert "replication/lag_batches/far" in gauges  # surviving replica stays

    promoted = g.replicator.stores["near"]
    assert g.fs.online is promoted  # writes re-pointed at the new primary
    post = promoted.dump_all("act", 1)
    assert set(post.names) == set(pre_failure.names)
    for name in post.names:
        np.testing.assert_array_equal(post[name], pre_failure[name], err_msg=name)

    # offline plane followed: the promoted region's offline store holds the
    # lost home's exact history (same full-key set and values), and the
    # home FeatureStore's offline plane IS that store now
    promoted_off = g.replicator.offline_stores["near"]
    assert g.fs.offline is promoted_off
    assert g.fs.materializer.offline is promoted_off
    post_off = promoted_off.canonical_history("act", 1)
    assert set(post_off.names) == set(pre_failure_off.names)
    assert len(post_off) == len(pre_failure_off)
    for name in post_off.names:
        np.testing.assert_array_equal(
            post_off[name], pre_failure_off[name], err_msg=name
        )

    # the surviving replica keeps replicating from the new home
    g.tick(now=7 * HOUR)
    g.drain()
    assert_planes_identical(g, "far", spec, "post-failover chain")
    vals2, found2, route2 = g.get_online_features(
        "act", 1, ids, consumer_region="far"
    )
    assert route2 == {"region": "far", "modeled_ms": 1.0}

    # the recovered ex-home rejoins via delta bootstrap and converges too
    g.mark_up("home")
    info = g.rejoin("home")
    assert info["online_rows"] > 0 and info["offline_rows"] > 0
    g.tick(now=8 * HOUR)
    g.drain()
    assert_planes_identical(g, "home", spec, "rejoined ex-home")
