"""Serving front (core/serving.py): the §2.1/§3.1.4 request plane.

The three contracts under test, in the order the ISSUE states them:

  * COALESCING IS INVISIBLE — a multi-caller batch the scheduler coalesces
    into one store dispatch returns byte-identical rows (values, hit mask,
    creation_ts) to per-request ``lookup`` calls, including TTL-expired and
    missing keys, on BOTH engines.  Same with the hot-key cache on: cached
    rows must be indistinguishable from store rows.
  * THE CACHE IS COHERENT AND STALENESS IS BOUNDED — merges invalidate via
    ``merge_listeners`` (mark-stale, not drop), fresh serves never return a
    superseded row, and degraded overload serves never exceed the configured
    staleness bound (beyond it, the request sheds).
  * ADMISSION CONTROL DEGRADES BEFORE IT REJECTS — queue-over-budget
    requests fall back to bounded-staleness cache hits when possible and
    shed otherwise; deadline-driven ``pump`` dispatches exactly the queues
    whose head ticket can no longer wait.

Plus the retrace-churn satellite: request-size jitter within one pow2
bucket must NOT grow the jitted kernel's compile cache.
"""

import numpy as np
import pytest

from repro.core.assets import Entity, Feature, FeatureSetSpec, MaterializationSettings
from repro.core.dsl import UDFTransform
from repro.core.keys import encode_keys
from repro.core.monitoring import HealthMonitor
from repro.core.online_store import OnlineStore
from repro.core.serving import DONE, PENDING, SHED, ServingConfig, ServingFront
from repro.core.table import Table
from repro.kernels.online_lookup import ops as lookup_ops


def make_spec(ttl=None, n_feats=2):
    return FeatureSetSpec(
        name="fs",
        version=1,
        entity=Entity("cust", ("entity_id",)),
        features=tuple(Feature(f"f{i}") for i in range(n_feats)),
        source_name="src",
        transform=UDFTransform(lambda df, ctx: df, name="id"),
        materialization=MaterializationSettings(True, True, online_ttl=ttl),
    )


def make_frame(rng, n, id_hi, ev_hi, n_feats=2):
    cols = {
        "entity_id": rng.integers(0, id_hi, n).astype(np.int64),
        "ts": rng.integers(0, ev_hi, n).astype(np.int64),
    }
    for i in range(n_feats):
        cols[f"f{i}"] = rng.random(n).astype(np.float32)
    return Table(cols)


def seeded_store(*, ttl=None, engine="vector", seed=0):
    """Store with two merge generations (creation_ts 1_000 and 1_050), so a
    TTL of 100 at now=1_120 expires the older cohort only."""
    spec = make_spec(ttl=ttl)
    store = OnlineStore(num_partitions=4, merge_engine=engine)
    rng = np.random.default_rng(seed)
    store.merge(spec, make_frame(rng, 80, 40, 50), 1_000)
    store.merge(spec, make_frame(rng, 80, 40, 80), 1_050)
    return store, spec


def assert_ticket_matches_store(t, store, *, now, use_kernel):
    """The satellite-c oracle: ticket rows byte-identical to a per-request
    ``lookup_encoded`` for the same ids (values, found, creation_ts)."""
    vr, fr, cr = store.lookup_encoded("fs", 1, t.ids, now=now, use_kernel=use_kernel)
    np.testing.assert_array_equal(t.found, fr)
    np.testing.assert_array_equal(t.values, vr)
    np.testing.assert_array_equal(t.creation_ts, cr)


# -- coalescing parity (satellite c) ------------------------------------------


@pytest.mark.parametrize("engine", ["host", "kernel"])
def test_coalesced_batch_identical_to_per_request(engine):
    """Multiple callers' GETs — overlapping ids, missing ids, TTL-expired
    ids — coalesce into ONE dispatch whose scattered results are
    byte-identical to per-request lookups on the same engine."""
    store_engine = "kernel" if engine == "kernel" else "vector"
    store, _ = seeded_store(ttl=100, engine=store_engine)
    front = ServingFront(store, config=ServingConfig(cache_capacity=0))
    now = 1_120  # gen-1 rows (creation 1_000) expired, gen-2 (1_050) live
    use_kernel = engine == "kernel"

    callers = [
        [np.arange(0, 15, dtype=np.int64)],  # mix of live/expired
        [np.arange(10, 30, dtype=np.int64)],  # overlaps caller 0
        [np.arange(35, 60, dtype=np.int64)],  # ids >= 40 never written
        [np.array([7, 7, 1000, 3], dtype=np.int64)],  # dupes + far miss
    ]
    tickets = [front.submit("fs", 1, ids, now=now) for ids in callers]
    assert all(t.status == PENDING for t in tickets)
    assert front.flush("fs", 1, engine=engine, now=now) == 1  # ONE dispatch
    for t in tickets:
        assert t.status == DONE
        assert_ticket_matches_store(t, store, now=now, use_kernel=use_kernel)
    s = front.stats()
    assert s["dispatches"] == 1
    assert s["coalesced_keys"] == sum(len(c[0]) for c in callers)
    assert s["unique_keys"] < s["coalesced_keys"]  # dedup actually happened
    # expired rows surface as misses with zeroed values and creation_ts
    t0 = tickets[0]
    assert not t0.found.all() and (t0.creation_ts[~t0.found] == 0).all()
    assert (t0.values[~t0.found] == 0).all()


@pytest.mark.parametrize("engine", ["host", "kernel"])
def test_cache_on_parity_and_coherence_across_merges(engine):
    """With the hot-key cache enabled, every GET — cold, cached, and after
    an invalidating merge — still matches the store exactly."""
    store_engine = "kernel" if engine == "kernel" else "vector"
    store, spec = seeded_store(ttl=500, engine=store_engine)
    front = ServingFront(store, config=ServingConfig(cache_capacity=128))
    rng = np.random.default_rng(42)
    use_kernel = engine == "kernel"
    now = 1_100
    for round_ in range(6):
        ids = [rng.integers(0, 50, 24).astype(np.int64)]
        t = front.submit("fs", 1, ids, now=now)
        if t.status == PENDING:
            front.flush("fs", 1, engine=engine, now=now)
        assert t.status == DONE
        assert_ticket_matches_store(t, store, now=now, use_kernel=use_kernel)
        if round_ % 2 == 1:  # interleave writes: cache must stay coherent
            store.merge(spec, make_frame(rng, 30, 50, 200 + round_), 1_200 + round_)
            now = 1_250 + round_
    assert front.stats()["cache_hits"] > 0
    assert front.stats()["cache_invalidations"] > 0


def test_cached_row_expires_like_the_store():
    """A cached FOUND row past its TTL serves as a miss — the cache re-checks
    TTL from the stored creation_ts at serve time, exactly like the store."""
    store, _ = seeded_store(ttl=100)
    front = ServingFront(store, config=ServingConfig(cache_capacity=64))
    ids = [np.arange(10, dtype=np.int64)]
    v1, f1 = front.get("fs", 1, ids, now=1_060, engine="host")
    assert f1.any()
    # same keys, far future: every row expired; cache must agree with store
    t = front.submit("fs", 1, ids, now=10_000)
    if t.status == PENDING:
        front.flush("fs", 1, engine="host", now=10_000)
    assert_ticket_matches_store(t, store, now=10_000, use_kernel=False)
    assert not t.found.any()


def test_negative_caching_and_fastpath():
    """Missing keys cache too: the second identical request is served
    entirely from cache (zero additional dispatches), still all-miss."""
    store, _ = seeded_store()
    front = ServingFront(store, config=ServingConfig(cache_capacity=64))
    missing = [np.array([900, 901, 902], dtype=np.int64)]
    v1, f1 = front.get("fs", 1, missing, engine="host")
    assert not f1.any()
    d1 = front.stats()["dispatches"]
    v2, f2 = front.get("fs", 1, missing, engine="host")
    assert not f2.any()
    assert front.stats()["dispatches"] == d1  # pure cache fast path
    assert front.stats()["cache_fastpath"] >= 1


# -- hot-key cache mechanics --------------------------------------------------


def test_clock_eviction_bounds_cache_size():
    store, _ = seeded_store()
    front = ServingFront(store, config=ServingConfig(cache_capacity=8))
    for base in range(0, 40, 4):
        front.get(
            "fs", 1, [np.arange(base, base + 4, dtype=np.int64)], engine="host"
        )
    assert front.cache.size == 8
    assert front.cache.evictions > 0
    # hot key survives the clock hand: touch it between eviction pressure
    hot = [np.array([2], dtype=np.int64)]
    front.get("fs", 1, hot, engine="host")
    for base in range(100, 120, 4):
        front.get(
            "fs", 1, [np.arange(base, base + 4, dtype=np.int64)], engine="host"
        )
        front.get("fs", 1, hot, engine="host")  # keep ref bit set
    hot_key = int(encode_keys(hot)[0])
    assert front.cache.get(("fs", 1), hot_key) is not None


def test_mark_stale_vectorized_large_merge():
    """A merge touching far more keys than the cache holds must invalidate
    correctly through the vectorized np.isin path."""
    spec = make_spec()
    store = OnlineStore(num_partitions=4, merge_engine="vector")
    rng = np.random.default_rng(1)
    store.merge(spec, make_frame(rng, 2_000, 1_000, 50), 1_000)
    front = ServingFront(store, config=ServingConfig(cache_capacity=16))
    ids = [np.arange(16, dtype=np.int64)]
    front.get("fs", 1, ids, now=1_100, engine="host")
    assert front.cache.size == 16
    # touches ~1000 distinct ids >> 16 cached entries
    store.merge(spec, make_frame(rng, 2_000, 1_000, 60), 2_000)
    stale = [
        e
        for e in front.cache._tables[("fs", 1)].values()
        if e.stale_since is not None
    ]
    assert len(stale) == front.cache.invalidations > 0
    assert all(e.stale_since == 2_000 for e in stale)
    # and a fresh GET returns post-merge truth
    t = front.submit("fs", 1, ids, now=2_100)
    if t.status == PENDING:
        front.flush("fs", 1, engine="host", now=2_100)
    assert_ticket_matches_store(t, store, now=2_100, use_kernel=False)


def test_first_superseding_write_wins_staleness_onset():
    store, spec = seeded_store()
    front = ServingFront(store, config=ServingConfig(cache_capacity=64))
    ids = [np.arange(8, dtype=np.int64)]
    front.get("fs", 1, ids, now=1_100, engine="host")
    rng = np.random.default_rng(5)
    s1 = store.merge(spec, make_frame(rng, 40, 8, 100), 2_000)
    s2 = store.merge(spec, make_frame(rng, 40, 8, 120), 3_000)  # second supersede
    entries = front.cache._tables[("fs", 1)]
    twice = set(map(int, s1["touched_keys"])) & set(map(int, s2["touched_keys"]))
    assert twice  # both merges overwrote at least one cached id
    for k in twice:
        if k in entries:
            # ages from the FIRST superseding merge, never resets
            assert entries[k].stale_since == 2_000


# -- admission control / load shedding ----------------------------------------


def overloaded_front(store, **cfg):
    """max_queue_keys=0 makes every residual over-budget, forcing the
    degrade-or-shed decision deterministically."""
    return ServingFront(
        store,
        config=ServingConfig(cache_capacity=64, max_queue_keys=0, **cfg),
    )


def test_overload_degrades_to_bounded_staleness_hits():
    store, spec = seeded_store(ttl=100_000)
    # warm phase: normal config fills the cache
    warm = ServingFront(store, config=ServingConfig(cache_capacity=64))
    ids = [np.arange(10, dtype=np.int64)]
    v_warm, f_warm = warm.get("fs", 1, ids, now=1_100, engine="host")
    # supersede every cached row at ts=2_000, then overload
    rng = np.random.default_rng(9)
    store.merge(spec, make_frame(rng, 60, 10, 150), 2_000)
    warm.config.max_queue_keys = 0
    bound = warm.config.staleness_bound_ms  # default 2_000
    t = warm.submit("fs", 1, ids, now=2_000 + bound)  # age == bound: allowed
    assert t.status == DONE and t.degraded
    assert t.stale_age_ms == bound
    assert warm.max_stale_age_ms <= bound  # the in-test staleness assertion
    # degraded result is the superseded snapshot, not the new truth
    np.testing.assert_array_equal(t.values, v_warm)
    np.testing.assert_array_equal(t.found, f_warm)


def test_overload_sheds_beyond_staleness_bound():
    store, spec = seeded_store(ttl=100_000)
    warm = ServingFront(store, config=ServingConfig(cache_capacity=64))
    ids = [np.arange(10, dtype=np.int64)]
    warm.get("fs", 1, ids, now=1_100, engine="host")
    rng = np.random.default_rng(9)
    store.merge(spec, make_frame(rng, 60, 10, 150), 2_000)
    warm.config.max_queue_keys = 0
    bound = warm.config.staleness_bound_ms
    t = warm.submit("fs", 1, ids, now=2_001 + bound)  # one ms too old
    assert t.status == SHED
    assert warm.stats()["shed"] == 1
    assert warm.max_stale_age_ms == 0.0  # nothing stale was ever served


def test_overload_sheds_on_cold_cache_and_sync_get_raises():
    store, _ = seeded_store()
    front = overloaded_front(store)
    t = front.submit("fs", 1, [np.arange(4, dtype=np.int64)], now=1_100)
    assert t.status == SHED  # nothing cached -> nothing to degrade to
    with pytest.raises(RuntimeError, match="shed"):
        front.get("fs", 1, [np.arange(4, dtype=np.int64)], now=1_100)


def test_deadline_admission_uses_projected_wait():
    """A request whose projected queue wait exceeds its deadline is refused
    at admission even though the hard queue bound has room."""
    store, _ = seeded_store()
    front = ServingFront(
        store, config=ServingConfig(cache_capacity=0, deadline_ms=10.0)
    )
    front._ema_keys_per_ms = 1.0  # calibrated: 1 key per ms
    ok = front.submit("fs", 1, [np.arange(5, dtype=np.int64)])  # ~5ms: fits
    assert ok.status == PENDING
    # queue now 5 keys; +20 more projects 25ms >> 10ms deadline
    t = front.submit("fs", 1, [np.arange(20, dtype=np.int64)])
    assert t.status == SHED
    # an explicit generous deadline still gets in
    t2 = front.submit(
        "fs", 1, [np.arange(20, dtype=np.int64)], deadline_ms=1_000.0
    )
    assert t2.status == PENDING
    front.flush("fs", 1, engine="host")
    assert ok.status == DONE and t2.status == DONE


def test_pump_dispatches_on_deadline_pressure():
    rt = {"now": 0.0}
    store, _ = seeded_store()
    front = ServingFront(
        store,
        config=ServingConfig(cache_capacity=0, deadline_ms=50.0),
        request_clock=lambda: rt["now"],
    )
    t = front.submit("fs", 1, [np.arange(6, dtype=np.int64)], now=1_100)
    assert t.status == PENDING
    assert front.pump(now=1_100) == 0  # fresh ticket: plenty of budget left
    rt["now"] = 49.0
    assert front.pump(now=1_100) == 0
    rt["now"] = 50.0  # waited >= deadline: due now
    assert front.pump(now=1_100) == 1
    assert t.status == DONE
    assert_ticket_matches_store(t, store, now=1_100, use_kernel=False)


def test_batch_size_trigger_auto_flushes():
    store, _ = seeded_store()
    front = ServingFront(
        store, config=ServingConfig(cache_capacity=0, max_batch_keys=32)
    )
    t1 = front.submit("fs", 1, [np.arange(20, dtype=np.int64)], now=1_100)
    assert t1.status == PENDING  # 20 < 32: waits for company
    t2 = front.submit("fs", 1, [np.arange(20, 40, dtype=np.int64)], now=1_100)
    # 40 >= 32: the scheduler flushed without an explicit flush() call
    assert t1.status == DONE and t2.status == DONE
    assert front.stats()["queued_keys"] == 0


def test_flush_splits_oversized_queues():
    store, _ = seeded_store()
    front = ServingFront(
        store, config=ServingConfig(cache_capacity=0, max_batch_keys=16)
    )
    tickets = [
        front.submit("fs", 1, [np.arange(b, b + 10, dtype=np.int64)], now=1_100)
        for b in (0, 10, 20)
    ]
    # second submit tips the queue to 20 >= 16: auto-flush drains it in
    # whole-ticket chunks of <= 16 keys -> one dispatch per 10-key ticket
    assert front.stats()["dispatches"] == 2
    assert tickets[2].status == PENDING  # third arrived after the drain
    front.flush("fs", 1, engine="host", now=1_100)
    assert front.stats()["dispatches"] == 3
    assert all(t.status == DONE for t in tickets)
    for t in tickets:
        assert_ticket_matches_store(t, store, now=1_100, use_kernel=False)


# -- store rebinding (failover) -----------------------------------------------


def test_front_rebinds_after_store_swap():
    """Failover re-points the store reference: the front notices on the next
    request — cache dropped, merge listener moved to the promoted store."""
    store_a, spec = seeded_store(seed=0)
    store_b, _ = seeded_store(seed=99)  # different data
    holder = {"store": store_a}
    front = ServingFront(
        lambda: holder["store"], config=ServingConfig(cache_capacity=64)
    )
    ids = [np.arange(12, dtype=np.int64)]
    front.get("fs", 1, ids, now=1_100, engine="host")
    assert front.cache.size > 0
    assert len(store_a.merge_listeners) == 1

    holder["store"] = store_b  # the failover
    v, f = front.get("fs", 1, ids, now=1_100, engine="host")
    vb, fb = store_b.lookup("fs", 1, ids, now=1_100, use_kernel=False)
    np.testing.assert_array_equal(v, vb)
    np.testing.assert_array_equal(f, fb)
    assert store_a.merge_listeners == []  # unsubscribed from the old store
    assert len(store_b.merge_listeners) == 1
    # old store's merges no longer touch the (new) cache
    rng = np.random.default_rng(3)
    store_a.merge(spec, make_frame(rng, 20, 12, 300), 5_000)
    assert front.cache.invalidations == 0


# -- FeatureStore integration -------------------------------------------------


def test_featurestore_default_front_is_passthrough():
    """The default FeatureStore serving config must not change GET semantics:
    no cache, no admission control — byte-identical to OnlineStore.lookup."""
    from repro.core.featurestore import FeatureStore

    fs = FeatureStore("serve-pt")
    assert fs.serving.config.cache_capacity == 0
    assert fs.serving.config.deadline_ms is None
    spec = make_spec(ttl=100)
    fs.registry.create_entity(spec.entity)
    fs._sources["src"] = None  # direct-merge path; no scheduler involved
    fs.create_feature_set(spec)
    rng = np.random.default_rng(0)
    fs.online.merge(spec, make_frame(rng, 80, 40, 50), 1_000)
    fs.advance_clock(1_060)
    ids = [np.arange(30, dtype=np.int64)]
    for use_kernel in (False, True):
        v, f = fs.get_online_features("fs", 1, ids, use_kernel=use_kernel)
        vr, fr = fs.online.lookup(
            "fs", 1, ids, now=fs.clock(), use_kernel=use_kernel
        )
        np.testing.assert_array_equal(f, fr)
        np.testing.assert_array_equal(v, vr)
    snap = fs.monitor.system.snapshot()
    assert snap["histograms"]["serving/kernel_us"]["n"] >= 1  # stages observed


def test_featurestore_with_serving_config_caches():
    from repro.core.featurestore import FeatureStore

    fs = FeatureStore("serve-cache", serving=ServingConfig(cache_capacity=256))
    spec = make_spec()
    fs.registry.create_entity(spec.entity)
    fs._sources["src"] = None
    fs.create_feature_set(spec)
    rng = np.random.default_rng(0)
    fs.online.merge(spec, make_frame(rng, 80, 40, 50), 1_000)
    ids = [np.arange(30, dtype=np.int64)]
    v1, f1 = fs.get_online_features("fs", 1, ids, use_kernel=False)
    v2, f2 = fs.get_online_features("fs", 1, ids, use_kernel=False)
    np.testing.assert_array_equal(v1, v2)
    np.testing.assert_array_equal(f1, f2)
    assert fs.serving.stats()["cache_fastpath"] >= 1
    # materializer merges flow through merge_listeners -> invalidation works
    fs.online.merge(spec, make_frame(rng, 40, 40, 90), 2_000)
    v3, _ = fs.get_online_features("fs", 1, ids, use_kernel=False)
    vr, _ = fs.online.lookup("fs", 1, ids, now=fs.clock(), use_kernel=False)
    np.testing.assert_array_equal(v3, vr)


# -- retrace churn (satellite a) ----------------------------------------------


def test_pow2_bucket_rule():
    assert lookup_ops.pow2_bucket(1) == 128  # floor
    assert lookup_ops.pow2_bucket(128) == 128
    assert lookup_ops.pow2_bucket(129) == 256
    assert lookup_ops.pow2_bucket(1_500) == 2_048
    assert lookup_ops.pow2_bucket(2_048) == 2_048
    assert lookup_ops.pow2_bucket(2_049) == 4_096
    # the store's _bucket IS this rule (one bucketing policy, not two)
    from repro.core import online_store

    assert online_store._bucket is lookup_ops.pow2_bucket


def test_kernel_get_compile_count_stable_across_batch_jitter():
    """Request-size jitter within one pow2 bucket reuses the SAME compiled
    kernel entry: after a warm-up GET, repeated kernel GETs with varying
    batch sizes must not grow either jit cache (the retrace-churn fix —
    the old next-multiple-of-128 padding re-traced per high-water mark)."""
    spec = make_spec(n_feats=1)
    store = OnlineStore(num_partitions=16, merge_engine="vector")
    rng = np.random.default_rng(0)
    # one merge only: capacity must not change between GETs
    frame = make_frame(rng, 6_000, 1 << 40, 100, n_feats=1)
    store.merge(spec, frame, 1_000)

    def get(seed, b):
        r = np.random.default_rng(seed)
        ids = [r.integers(0, 1 << 40, b).astype(np.int64)]
        store.lookup("fs", 1, ids, now=1_050, use_kernel=True)

    get(0, 5_700)  # warm-up: compiles this bucket once
    c_lookup = lookup_ops.lookup._cache_size()
    c_gather = lookup_ops.gather_rows._cache_size()
    # b in [5400, 6000]: routed qmax jitters run-to-run (mean ~356, sd ~18)
    # but stays inside the (256, 512] pow2 bucket; gather stays in 8192
    for seed, b in enumerate((5_400, 5_550, 5_700, 5_850, 6_000), start=1):
        get(seed, b)
        assert lookup_ops.lookup._cache_size() == c_lookup, (seed, b)
        assert lookup_ops.gather_rows._cache_size() == c_gather, (seed, b)


# -- monitoring wiring --------------------------------------------------------


def test_per_stage_histograms_populated():
    store, _ = seeded_store()
    mon = HealthMonitor()
    front = ServingFront(
        store, config=ServingConfig(cache_capacity=32), monitor=mon
    )
    rng = np.random.default_rng(0)
    for _ in range(4):
        front.get(
            "fs",
            1,
            [rng.integers(0, 40, 16).astype(np.int64)],
            now=1_100,
            engine="host",
        )
    snap = mon.system.snapshot()
    for stage in ("queue_wait", "assembly", "kernel", "decode", "request"):
        h = snap["histograms"][f"serving/{stage}_us"]
        assert h["n"] >= 1, stage
        assert h["p50"] >= 0 and h["p99"] >= h["p50"] * (1 - 1e-9), stage
    assert mon.system.counters["serving/requests"] == 4
