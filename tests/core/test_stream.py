"""Stream-framing properties for the socket carrier (ISSUE 8 satellite).

The wire v2 frame is NOT self-delimiting on a byte stream (its header
carries the raw length, not the compressed length), so ``core/daemon.py``
wraps every message in ``wire.frame_message``'s u32 length-prefix envelope
and reassembles with ``wire.StreamDecoder``.  The contracts under test:

  * REASSEMBLY — any partition of the byte stream into recv-sized chunks
    (byte-at-a-time through whole-stream) yields the identical event
    sequence, with every ``encode_run`` payload decoding back to the same
    batches the in-process path would have produced;
  * CONCATENATION — back-to-back messages of mixed kinds (frames, acks,
    controls) come out one event each, in order;
  * TRUNCATION — an incomplete tail yields nothing (no partial events,
    no exception) until the missing bytes arrive;
  * CORRUPTION — a payload flip inside an intact envelope produces one
    "corrupt" event and the stream stays aligned (every later message
    still decodes); a torn envelope triggers a resync scan that finds the
    next real message boundary and counts the bytes skipped.
"""

import struct
import zlib

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import wire
from tests.core.test_wire import (
    assert_batches_equal,
    random_offline_batch,
    random_online_batch,
)


def _run_payload(rng, seq0=0, n=3, plane="online"):
    mk = random_online_batch if plane == "online" else random_offline_batch
    batches = [mk(rng, seq=seq0 + i) for i in range(n)]
    return batches, wire.encode_run(batches).data


def _feed_chunked(dec, stream, chunk):
    events = []
    for i in range(0, len(stream), chunk):
        events.extend(dec.feed(stream[i : i + chunk]))
    return events


# -- reassembly ---------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    chunk=st.integers(min_value=1, max_value=257),
    n_msgs=st.integers(min_value=1, max_value=5),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_any_chunking_reassembles_identically(chunk, n_msgs, seed):
    """Property: recv boundaries are invisible.  The same byte stream cut
    at EVERY chunk size yields the same events, and each frame's batches
    round-trip bit-exact against the ``encode_run`` input."""
    rng = np.random.default_rng(seed)
    all_batches, stream = [], b""
    for m in range(n_msgs):
        plane = "online" if (seed + m) % 2 else "offline"
        batches, payload = _run_payload(rng, seq0=10 * m, n=2, plane=plane)
        all_batches.append(batches)
        stream += wire.frame_message(payload)

    events = _feed_chunked(wire.StreamDecoder(), stream, chunk)
    assert [e.kind for e in events] == ["frame"] * n_msgs
    for want, ev in zip(all_batches, events):
        assert len(ev.batches) == len(want)
        for a, b in zip(want, ev.batches):
            assert_batches_equal(a, b)


def test_single_message_split_across_every_boundary():
    """Exhaustive split of one envelope at every byte offset — including
    splits inside the length prefix and inside the magic."""
    rng = np.random.default_rng(3)
    batches, payload = _run_payload(rng, n=1)
    stream = wire.frame_message(payload)
    for cut in range(1, len(stream)):
        dec = wire.StreamDecoder()
        assert dec.feed(stream[:cut]) == []  # nothing premature
        (ev,) = dec.feed(stream[cut:])
        assert ev.kind == "frame"
        assert_batches_equal(batches[0], ev.batches[0])
        assert dec.buffered_bytes == 0


def test_concatenated_mixed_kinds_fed_whole():
    """Frames, control messages, and acks glued end to end decode in
    order, one event each, regardless of kind interleaving."""
    rng = np.random.default_rng(11)
    _, frame_payload = _run_payload(rng, n=2)
    ctrl = wire.encode_control({"cmd": "ledger", "token": 7})
    ack = wire.encode_ack(wire.ACK_OK, 0xDEAD, 42, [5, 6, 7])
    stream = b"".join(
        wire.frame_message(p) for p in (ctrl, frame_payload, ack, frame_payload)
    )
    dec = wire.StreamDecoder()
    events = dec.feed(stream)
    assert [e.kind for e in events] == ["control", "frame", "ack", "frame"]
    assert events[0].control == {"cmd": "ledger", "token": 7}
    assert events[2].ack.seqs == (5, 6, 7)
    assert events[2].ack.rows == 42
    assert dec.messages == 4 and dec.corrupt_messages == 0 and dec.resyncs == 0


# -- truncation ---------------------------------------------------------------


def test_truncated_tail_yields_nothing_until_completed():
    """A message cut short emits no event and no error; delivering the
    missing suffix later completes it."""
    rng = np.random.default_rng(5)
    batches, payload = _run_payload(rng, n=1)
    stream = wire.frame_message(payload)
    dec = wire.StreamDecoder()
    assert dec.feed(stream[:-9]) == []
    assert dec.buffered_bytes == len(stream) - 9
    (ev,) = dec.feed(stream[-9:])
    assert ev.kind == "frame"
    assert_batches_equal(batches[0], ev.batches[0])


# -- corruption ---------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31),
    chunk=st.integers(min_value=1, max_value=97),
)
def test_payload_corruption_keeps_stream_aligned(seed, chunk):
    """Property: flip one byte INSIDE a message payload (envelope intact).
    The damaged message surfaces as a single "corrupt" event carrying the
    as-received crc (the NACK token) and every later message still
    decodes — corruption never desynchronizes the stream."""
    rng = np.random.default_rng(seed)
    _, p1 = _run_payload(rng, seq0=0)
    batches2, p2 = _run_payload(rng, seq0=10)
    # flip a byte past the magic so the envelope still looks like a frame
    pos = 2 + int(rng.integers(0, len(p1) - 2))
    bad = p1[:pos] + bytes([p1[pos] ^ 0xA5]) + p1[pos + 1 :]
    stream = wire.frame_message(bad) + wire.frame_message(p2)

    dec = wire.StreamDecoder()
    events = _feed_chunked(dec, stream, chunk)
    assert [e.kind for e in events] == ["corrupt", "frame"]
    assert events[0].msg_crc == zlib.crc32(bad)
    for a, b in zip(batches2, events[1].batches):
        assert_batches_equal(a, b)
    assert dec.corrupt_messages == 1 and dec.resyncs == 0


def test_torn_envelope_resyncs_to_next_boundary():
    """Garbage between two messages (a torn length prefix) triggers the
    resync scan: the decoder skips to the next plausible boundary and the
    following message decodes normally."""
    rng = np.random.default_rng(9)
    batches1, p1 = _run_payload(rng, seq0=0, n=1)
    batches2, p2 = _run_payload(rng, seq0=5, n=1)
    garbage = b"\xff" * 4 + b"ZZ" + b"\x00" * 14  # implausible len + bad magic
    stream = wire.frame_message(p1) + garbage + wire.frame_message(p2)

    dec = wire.StreamDecoder()
    events = dec.feed(stream)
    assert [e.kind for e in events] == ["frame", "frame"]
    assert_batches_equal(batches1[0], events[0].batches[0])
    assert_batches_equal(batches2[0], events[1].batches[0])
    assert dec.resyncs >= 1
    assert dec.skipped_bytes == len(garbage)


def test_resync_under_tiny_chunks_terminates():
    """Pathological case: pure garbage fed a byte at a time must neither
    loop forever nor blow the buffer — the decoder keeps only a 5-byte
    tail while scanning."""
    dec = wire.StreamDecoder()
    for b in bytes(range(256)) * 4:
        dec.feed(bytes([b]))
    assert dec.buffered_bytes <= 16
    # and a real message after the noise still gets through
    rng = np.random.default_rng(2)
    batches, payload = _run_payload(rng, n=1)
    events = _feed_chunked(dec, wire.frame_message(payload), 7)
    assert [e.kind for e in events][-1] == "frame"
    assert_batches_equal(batches[0], events[-1].batches[0])


def test_ack_and_control_crc_reject():
    """Damaged ack/control payloads inside intact envelopes surface as
    corrupt events, not exceptions, and do not derail later traffic."""
    ack = bytearray(wire.encode_ack(wire.ACK_OK, 1, 2, [3]))
    ack[-1] ^= 0x40
    ctrl = bytearray(wire.encode_control({"cmd": "hello"}))
    ctrl[-2] ^= 0x01
    good = wire.encode_control({"cmd": "hello"})
    stream = b"".join(
        wire.frame_message(bytes(p)) for p in (ack, ctrl, good)
    )
    dec = wire.StreamDecoder()
    events = dec.feed(stream)
    assert [e.kind for e in events] == ["corrupt", "corrupt", "control"]
    assert dec.corrupt_messages == 2


def test_frame_message_bounds():
    """The envelope refuses payloads it could never reassemble."""
    with pytest.raises(wire.WireFormatError):
        wire.frame_message(b"x")  # below the 2-byte magic minimum
    wrapped = wire.frame_message(b"FWok")
    (n,) = struct.unpack_from("<I", wrapped, 0)
    assert n == 4 and wrapped[4:] == b"FWok"
