"""Merge-engine parity: vectorized write paths vs the retained loop reference.

The contract under test (ISSUE tentpole): for ANY merge sequence, the
``vector`` (and online ``kernel``) engines must leave the stores in
BYTE-IDENTICAL state to the sequential Algorithm-2 loop — same table planes,
same sorted indexes, same chunk contents — with identical
``inserts/overrides/noops`` / ``rows_merged/rows_deduped`` tallies.
Covers duplicate ids within one batch, equal-event_ts creation-ts tiebreaks,
TTL sweeps between merges, and growth/compaction boundaries.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.assets import Entity, Feature, FeatureSetSpec, MaterializationSettings
from repro.core.dsl import UDFTransform
from repro.core.merge_engine import (
    INT64_MIN,
    merge_sorted,
    plan_online_batch,
    segmented_exclusive_prefix_max,
)
from repro.core.offline_store import OfflineStore
from repro.core.online_store import OnlineStore
from repro.core.table import Table

_ONLINE_STATE = (
    "keys_lo", "keys_hi", "keys_full", "event_ts", "creation_ts",
    "values", "fill", "idx_keys", "idx_part", "idx_slot",
)


def make_spec(ttl=None, n_feats=1):
    return FeatureSetSpec(
        name="fs",
        version=1,
        entity=Entity("cust", ("entity_id",)),
        features=tuple(Feature(f"f{i}") for i in range(n_feats)),
        source_name="src",
        transform=UDFTransform(lambda df, ctx: df, name="id"),
        materialization=MaterializationSettings(True, True, online_ttl=ttl),
    )


def make_frame(rng, n, id_hi, ev_hi, n_feats=1):
    cols = {
        "entity_id": rng.integers(0, id_hi, n).astype(np.int64),
        "ts": rng.integers(0, ev_hi, n).astype(np.int64),
    }
    for i in range(n_feats):
        cols[f"f{i}"] = rng.random(n).astype(np.float32)
    return Table(cols)


def assert_online_identical(a: OnlineStore, b: OnlineStore, spec, label=""):
    # device-resident engines keep truth on device; pull the lazy host
    # mirrors up to date before comparing planes byte-for-byte
    a.sync_host_mirrors()
    b.sync_host_mirrors()
    ta, tb = a._tables[spec.key], b._tables[spec.key]
    for f in _ONLINE_STATE:
        np.testing.assert_array_equal(
            getattr(ta, f), getattr(tb, f), err_msg=f"{label}: plane {f}"
        )
    assert [list(f) for f in ta.free] == [list(f) for f in tb.free], (
        f"{label}: free lists"
    )
    assert (a.inserts, a.overrides, a.noops) == (b.inserts, b.overrides, b.noops), label


def assert_offline_identical(a: OfflineStore, b: OfflineStore, spec, label=""):
    assert a.read("fs", 1).equals(b.read("fs", 1)), label
    assert a.num_rows("fs", 1) == b.num_rows("fs", 1), label
    assert (a.rows_merged, a.rows_deduped) == (b.rows_merged, b.rows_deduped), label
    for i, (sa, sb) in enumerate(zip(a._shards[spec.key], b._shards[spec.key])):
        np.testing.assert_array_equal(
            sa.index, sb.index, err_msg=f"{label}: shard {i} index"
        )


# -- low-level engine pieces -------------------------------------------------


def test_segmented_prefix_max_vs_sequential():
    rng = np.random.default_rng(0)
    seg = np.sort(rng.integers(0, 40, 300))
    vals = rng.integers(-100, 100, 300)
    got = segmented_exclusive_prefix_max(seg, vals)
    run: dict = {}
    for i in range(300):
        want = run.get(seg[i], INT64_MIN)
        assert got[i] == want, i
        run[seg[i]] = max(want, vals[i])


def test_merge_sorted_matches_insert():
    rng = np.random.default_rng(1)
    a = np.unique(rng.integers(0, 1000, 80))
    b = np.unique(rng.integers(1000, 2000, 40))
    pa = rng.random(len(a))
    pb = rng.random(len(b))
    keys, payload = merge_sorted([a, pa], [b, pb])
    want_keys = np.insert(a, np.searchsorted(a, b), b)
    np.testing.assert_array_equal(keys, want_keys)
    np.testing.assert_array_equal(np.sort(payload), np.sort(np.r_[pa, pb]))
    assert (keys[np.argsort(keys, kind="stable")] == keys).all()


def test_plan_counters_match_sequential_loop():
    """plan_online_batch's tallies vs a literal Algorithm-2 interpreter."""
    rng = np.random.default_rng(2)
    for trial in range(30):
        n = int(rng.integers(1, 60))
        ids = rng.integers(0, 8, n).astype(np.int64)
        ev = rng.integers(0, 6, n).astype(np.int64)
        cr = int(rng.integers(10, 14))
        # simulated store: some ids present with random (ev, cr)
        state = {
            int(i): (int(rng.integers(0, 6)), int(rng.integers(8, 16)))
            for i in range(8)
            if rng.random() < 0.5
        }
        uids = np.unique(ids)
        old_ev = np.array([state.get(int(u), (0, 0))[0] for u in uids], np.int64)
        old_cr = np.array([state.get(int(u), (0, 0))[1] for u in uids], np.int64)
        found = np.array([int(u) in state for u in uids])
        plan = plan_online_batch(
            ids, ev, cr, lambda u: (old_ev, old_cr, found)
        )
        # sequential reference
        sim = dict(state)
        ins = ovr = nop = 0
        for i in range(n):
            k = int(ids[i])
            if k not in sim:
                sim[k] = (int(ev[i]), cr)
                ins += 1
            elif (int(ev[i]), cr) > sim[k]:
                sim[k] = (int(ev[i]), cr)
                ovr += 1
            else:
                nop += 1
        assert (plan.inserts, plan.overrides, plan.noops) == (ins, ovr, nop), trial
        # winners agree with the simulated end state for batch ids
        for g, u in enumerate(uids):
            want = sim[int(u)]
            if plan.beat[g]:
                assert (int(plan.winner_ev[g]), cr) == want, trial
            else:
                assert want == state[int(u)], trial


def test_encode_keys_string_width_independent():
    """A string id must hash identically regardless of the max width of the
    batch it arrives in — merge/lookup batches rarely share a width."""
    from repro.core.keys import encode_keys

    wide = encode_keys([np.array(["bob", "alexandria", "碧水"], dtype=object)])
    narrow = encode_keys([np.array(["bob"], dtype=object)])
    assert wide[0] == narrow[0]
    pair = encode_keys([np.array(["碧水", ""], dtype=object)])
    assert pair[0] == wide[2]
    # distinct values still disperse; empty string is stable
    assert len(np.unique(wide)) == 3
    assert pair[1] == encode_keys([np.array([""], dtype=object)])[0]


# -- online store: three engines, byte-identical ------------------------------


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    id_hi=st.integers(1, 40),
    ev_hi=st.integers(1, 8),
    n_batches=st.integers(1, 6),
)
def test_online_engines_byte_identical(seed, id_hi, ev_hi, n_batches):
    """Random merge sequences with heavy in-batch duplication and equal-ev
    ties: loop, vector, and kernel engines end byte-identical."""
    spec = make_spec()
    stores = {
        e: OnlineStore(num_partitions=4, initial_capacity=8, merge_engine=e)
        for e in ("loop", "vector", "kernel")
    }
    for b in range(n_batches):
        rng = np.random.default_rng(seed + b)
        frame = make_frame(rng, int(rng.integers(1, 120)), id_hi, ev_hi)
        cr = 10_000 + b * int(rng.integers(0, 2))  # repeated cr => cr ties
        for store in stores.values():
            store.merge(spec, frame, cr)
    assert_online_identical(stores["loop"], stores["vector"], spec, "vector")
    assert_online_identical(stores["loop"], stores["kernel"], spec, "kernel")


def test_online_growth_boundary_identical():
    """Inserts forcing repeated capacity doublings mid-batch land identically
    (same final capacity, same slot assignment) across engines."""
    spec = make_spec()
    rng = np.random.default_rng(3)
    ids = rng.permutation(np.arange(500, dtype=np.int64))
    frame = Table(
        {
            "entity_id": ids,
            "ts": np.full(500, 7, np.int64),
            "f0": rng.random(500).astype(np.float32),
        }
    )
    stores = {
        e: OnlineStore(num_partitions=2, initial_capacity=4, merge_engine=e)
        for e in ("loop", "vector", "kernel")
    }
    for store in stores.values():
        store.merge(spec, frame, 100)
    assert_online_identical(stores["loop"], stores["vector"], spec, "grow/vector")
    assert_online_identical(stores["loop"], stores["kernel"], spec, "grow/kernel")
    assert stores["loop"]._tables[spec.key].keys_lo.shape[1] >= 256


def test_online_ttl_sweep_interleaved_identical():
    """TTL expiry + sweep between merges: freed ids re-insert identically."""
    spec = make_spec(ttl=50)
    stores = {
        e: OnlineStore(num_partitions=2, initial_capacity=8, merge_engine=e)
        for e in ("loop", "vector", "kernel")
    }
    rng = np.random.default_rng(4)
    for step, (cr, sweep_at) in enumerate([(100, None), (160, 130), (220, 215)]):
        frame = make_frame(rng, 40, 12, 5)
        for store in stores.values():
            if sweep_at is not None:
                store.sweep("fs", 1, now=sweep_at)
            store.merge(spec, frame, cr)
    assert_online_identical(stores["loop"], stores["vector"], spec, "ttl/vector")
    assert_online_identical(stores["loop"], stores["kernel"], spec, "ttl/kernel")
    # expired records invisible to both lookup paths
    for store in stores.values():
        _, found = store.lookup(
            "fs", 1, [np.arange(12)], now=10_000, use_kernel=False
        )
        assert not found.any()


def test_online_equal_event_ts_tiebreak_counters():
    """Same event_ts, later creation_ts overrides ONCE; in-batch equal-ev
    duplicates are no-ops.  Exact counters on a hand-checked sequence."""
    spec = make_spec()
    for engine in ("loop", "vector", "kernel"):
        s = OnlineStore(num_partitions=2, merge_engine=engine)
        f1 = Table(
            {
                "entity_id": np.array([5, 5, 5], np.int64),
                "ts": np.array([10, 10, 10], np.int64),
                "f0": np.array([1.0, 2.0, 3.0], np.float32),
            }
        )
        s.merge(spec, f1, 100)  # insert + 2 in-batch equal-ev no-ops
        s.merge(spec, f1, 200)  # cr tiebreak: 1 override + 2 no-ops
        s.merge(spec, f1, 150)  # stale cr: 3 no-ops
        assert (s.inserts, s.overrides, s.noops) == (1, 1, 7), engine
        rec = s.get_record("fs", 1, [np.array([5])])[0]
        # first row of the winning batch carries the value
        assert rec["features"][0] == 1.0 and rec["creation_ts"] == 200, engine


# -- offline store: loop vs vector --------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    id_hi=st.integers(1, 30),
    ev_hi=st.integers(1, 10),
    n_batches=st.integers(1, 6),
)
def test_offline_engines_byte_identical(seed, id_hi, ev_hi, n_batches):
    """Random merges with replays (idempotence) + in-batch duplicate full
    keys: loop and vector end with identical chunks, counters, and index."""
    spec = make_spec()
    a = OfflineStore(num_shards=3, merge_engine="loop")
    b = OfflineStore(num_shards=3, merge_engine="vector")
    for i in range(n_batches):
        rng = np.random.default_rng(seed + i)
        frame = make_frame(rng, int(rng.integers(1, 100)), id_hi, ev_hi)
        cr = 1000 + i
        replay = rng.random() < 0.5  # retry replay: full dedup both paths
        for store in (a, b):
            store.merge(spec, frame, cr)
            if replay:
                store.merge(spec, frame, cr)
    assert_offline_identical(a, b, spec, f"seed={seed}")


def test_offline_compaction_boundary_identical():
    """Chunk-list compaction triggers at the same merge in both engines and
    never changes what ``read`` returns."""
    spec = make_spec()
    a = OfflineStore(num_shards=2, merge_engine="loop", compact_threshold=3)
    b = OfflineStore(num_shards=2, merge_engine="vector", compact_threshold=3)
    rng = np.random.default_rng(5)
    reads = []
    for i in range(8):
        frame = make_frame(rng, 20, 10, 5)
        for store in (a, b):
            store.merge(spec, frame, 1000 + i)
        reads.append(a.read("fs", 1).equals(b.read("fs", 1)))
    assert all(reads)
    assert_offline_identical(a, b, spec, "compaction")
    # compaction actually happened (chunk lists stayed bounded)
    assert all(
        len(s.chunks) <= 4 for s in a._shards[spec.key]
    ) and all(len(s.chunks) <= 4 for s in b._shards[spec.key])


def test_offline_latest_per_key_unchanged_by_engine():
    spec = make_spec()
    a = OfflineStore(num_shards=3, merge_engine="loop")
    b = OfflineStore(num_shards=3, merge_engine="vector")
    rng = np.random.default_rng(6)
    for cr in (1000, 2000, 3000):
        frame = make_frame(rng, 50, 15, 900)
        a.merge(spec, frame, cr)
        b.merge(spec, frame, cr)
    assert a.latest_per_key("fs", 1).equals(b.latest_per_key("fs", 1))
    assert a.time_partitions("fs", 1) == b.time_partitions("fs", 1)


# -- cross-store: the materialization path end-to-end -------------------------


def test_full_pipeline_engines_consistent():
    """Same frames through offline+online with each engine: every engine's
    store pair passes the §4.5.2 consistency check and agrees on state."""
    from repro.core.consistency import check_consistency

    spec = make_spec(n_feats=2)
    rng_seed = 9
    results = {}
    for engine in ("loop", "vector"):
        rng = np.random.default_rng(rng_seed)
        off = OfflineStore(num_shards=2, merge_engine=engine)
        on = OnlineStore(num_partitions=4, merge_engine=engine)
        for i in range(5):
            frame = make_frame(rng, 80, 25, 500, n_feats=2)
            off.merge(spec, frame, 10_000 + i)
            on.merge(spec, frame, 10_000 + i)
        assert check_consistency(spec, off, on).consistent, engine
        results[engine] = (off, on)
    assert_offline_identical(results["loop"][0], results["vector"][0], spec)
    assert_online_identical(results["loop"][1], results["vector"][1], spec)
