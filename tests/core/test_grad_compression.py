"""Error-feedback int8 gradient compression: unbiasedness + convergence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.optim.compression import GradCompressor


def test_roundtrip_error_bounded():
    comp = GradCompressor()
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 256))}
    r = comp.init(g)
    restored, r = comp.compress_decompress(g, r)
    err = jnp.abs(restored["w"] - g["w"]).max()
    scale = jnp.abs(g["w"]).max() / 127.0
    assert float(err) <= float(scale) * 1.01  # one quantization step


def test_error_feedback_accumulates_to_zero_bias():
    """Repeatedly compressing the SAME gradient must, summed over steps,
    deliver the true total (EF re-injects the quantization error)."""
    comp = GradCompressor()
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (32, 128)) * 0.01}
    r = comp.init(g)
    delivered = jnp.zeros_like(g["w"])
    n = 50
    for _ in range(n):
        restored, r = comp.compress_decompress(g, r)
        delivered = delivered + restored["w"]
    np.testing.assert_allclose(
        delivered / n, g["w"], rtol=0, atol=float(jnp.abs(g["w"]).max()) / 127 / 5
    )


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_sgd_with_compression_converges(seed):
    """Least-squares SGD with compressed grads converges like uncompressed."""
    rng = jax.random.PRNGKey(seed % 10_000)
    k1, k2 = jax.random.split(rng)
    A = jax.random.normal(k1, (64, 8))
    x_true = jax.random.normal(k2, (8,))
    y = A @ x_true

    def loss(x):
        return jnp.mean((A @ x - y) ** 2)

    comp = GradCompressor()
    x = jnp.zeros(8)
    r = comp.init({"x": x})
    for _ in range(300):
        g = jax.grad(loss)(x)
        restored, r = comp.compress_decompress({"x": g}, r)
        x = x - 0.05 * restored["x"]
    assert float(loss(x)) < 1e-3


def test_wire_bytes_ratio():
    """int8 + per-block f32 scales ≈ 1.03 bytes/param (4x less than f32)."""
    from repro.optim.adamw import quantize_q8

    g = jnp.zeros((1024, 1024))
    q = quantize_q8(g)
    wire = q["q"].size * 1 + q["scale"].size * 4
    assert wire / g.size < 1.05
