"""Sharded multi-home suite (ISSUE 9): routing properties + convergence.

The tentpole claims under test:

  * PARTITION — every encoded key maps to exactly one shard, that shard's
    ``[lo, hi)`` range contains the key's ``shard_coordinate``, and
    ``split_by_owner`` partitions a batch's row indices exactly (no row
    dropped, none duplicated, arrival order preserved per slice);
  * STABILITY — ``assign`` (the rebalance/failover cutover) rewrites only
    the moved range's owner: ownership of every key OUTSIDE the range is
    stable across any sequence of reassignments;
  * UNIFORMITY — routing happens in the ``keys.shard_coordinate`` space,
    so the small-id passthrough of ``encode_keys`` (ids returned unmixed)
    still spreads across all ranges instead of piling into shard 0;
  * AGREEMENT — the delta-bootstrap ``key_range`` filter masks on the
    SAME coordinate the router cuts on, so the rows a rebalance streams
    are exactly the rows the new owner will route to itself;
  * CONVERGENCE — concurrent writes entering at EVERY region converge the
    mesh byte-identical online / chunk-set-identical offline, including
    after per-shard failover, rejoin + rebalance, and graceful leave, and
    the steady state is echo-free (a drained mesh ships nothing more);
  * FACADE — ``FeatureStore``, ``GeoFeatureStore`` and
    ``MultiHomeGeoStore`` all satisfy the unified ``StoreFacade`` surface.

Property tests run under ``hypothesis`` when installed, else the seeded
deterministic fallback from ``tests/conftest.py`` — either way they always
execute.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.facade import StoreFacade
from repro.core.keys import KEY_SPACE_BITS, encode_keys, shard_coordinate
from repro.core.monitoring import HealthMonitor
from repro.core.multihome import MultiHomeGeoStore
from repro.core.regions import (
    GeoTopology,
    Region,
    RegionDownError,
    ShardMap,
)
from tests.core.test_replication import make_frame, make_spec

KEY_SPACE = 1 << KEY_SPACE_BITS
MH_REGIONS = ("r0", "r1", "r2")


def mh_topo():
    return GeoTopology(
        regions={r: Region(r) for r in MH_REGIONS},
        local_latency_ms=1.0,
        cross_region_latency_ms=60.0,
        link_latency_ms={
            ("r0", "r1"): 20.0,
            ("r1", "r2"): 30.0,
            ("r0", "r2"): 90.0,
        },
    )


def make_mh(**kw):
    kw.setdefault("topology", mh_topo())
    kw.setdefault("regions", list(MH_REGIONS))
    kw.setdefault("online_partitions", 4)
    mh = MultiHomeGeoStore("mh", **kw)
    mh.create_feature_set(make_spec())
    mh.advance_clock(10**9)
    return mh


def write_everywhere(mh, rng, *, rows=400, base_ts=10**7):
    """One concurrent ingest wave: a distinct batch enters at EVERY home."""
    return [
        mh.write_batch(
            "fs",
            1,
            make_frame(rng, rows, 5_000, 10**6),
            region=r,
            creation_ts=base_ts + i,
        )
        for i, r in enumerate(mh.regions())
    ]


def assert_mesh_identical(mh, ctx=""):
    """Drained-mesh invariant: every cell byte-identical online and
    chunk-set-identical offline (canonical_history sorts by full key)."""
    regions = mh.regions()
    ref_on = mh.online[regions[0]].dump_all("fs", 1)
    ref_off = mh.offline[regions[0]].canonical_history("fs", 1)
    for r in regions[1:]:
        d = mh.online[r].dump_all("fs", 1)
        for n in ref_on.names:
            np.testing.assert_array_equal(
                ref_on[n], d[n], err_msg=f"{ctx} [online {r}: {n}]"
            )
        h = mh.offline[r].canonical_history("fs", 1)
        assert len(ref_off) == len(h), f"{ctx} [offline {r}: row count]"
        for n in ref_off.names:
            np.testing.assert_array_equal(
                ref_off[n], h[n], err_msg=f"{ctx} [offline {r}: {n}]"
            )


# -- routing properties (hypothesis or the conftest fallback) -----------------


@settings(max_examples=60, deadline=None)
@given(
    st.integers(min_value=0, max_value=2**62),
    st.integers(min_value=2, max_value=5),
    st.integers(min_value=2, max_value=16),
)
def test_every_key_has_exactly_one_home(key, n_regions, n_shards):
    """Partition: one shard, whose coordinate range contains the key, and
    split_by_owner hands the key to exactly that shard's owner."""
    sm = ShardMap.even([f"h{i}" for i in range(n_regions)], n_shards)
    arr = np.array([key], np.int64)
    sid = int(sm.shard_of(arr)[0])
    assert 0 <= sid < sm.num_shards
    lo, hi = sm.shard_range(sid)
    coord = int(shard_coordinate(arr)[0])
    assert lo <= coord < hi
    split = sm.split_by_owner(arr)
    holders = [r for r, idx in split.items() if len(idx)]
    assert holders == [sm.owner_of(sid)]


@settings(max_examples=30, deadline=None)
@given(
    st.integers(min_value=0, max_value=2**32),
    st.integers(min_value=3, max_value=12),
    st.lists(st.integers(min_value=0, max_value=11), min_size=1, max_size=6),
)
def test_ownership_stable_outside_reassigned_ranges(seed, n_shards, moves):
    """Stability: an arbitrary sequence of assigns changes ownership ONLY
    for keys inside the reassigned ranges; shard ids never change at all
    (bounds are fixed at construction)."""
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 2**62, 512).astype(np.int64)
    sm = ShardMap.even(list(MH_REGIONS), n_shards)
    sids = sm.shard_of(keys)
    owners_before = np.array([sm.owner_of(int(s)) for s in sids])
    touched = set()
    for i, mv in enumerate(moves):
        sid = mv % n_shards
        sm.assign(sid, MH_REGIONS[i % len(MH_REGIONS)])
        touched.add(sid)
    np.testing.assert_array_equal(sm.shard_of(keys), sids)
    owners_after = np.array([sm.owner_of(int(s)) for s in sids])
    moved = owners_before != owners_after
    assert set(np.unique(sids[moved]).tolist()) <= touched
    assert sm.version == len(moves)


def test_split_by_owner_partitions_rows_in_arrival_order():
    rng = np.random.default_rng(7)
    keys = rng.integers(0, 2**62, 3_000).astype(np.int64)
    sm = ShardMap.even(list(MH_REGIONS), 9)  # several ranges per region
    split = sm.split_by_owner(keys)
    combined = np.sort(np.concatenate(list(split.values())))
    np.testing.assert_array_equal(combined, np.arange(len(keys)))
    sids = sm.shard_of(keys)
    for region, idx in split.items():
        assert np.all(np.diff(idx) > 0)  # arrival order, no duplicates
        assert all(sm.owner_of(int(s)) == region for s in sids[idx])


def test_small_passthrough_ids_spread_across_all_ranges():
    """The regression that motivated ``shard_coordinate``: encode_keys
    passes small single-column ids through unmixed, so routing on the raw
    encoded key piles every real-world id into shard 0."""
    ids = encode_keys([np.arange(3_000, dtype=np.int64)])
    sm = ShardMap.even(list(MH_REGIONS))
    counts = np.bincount(sm.shard_of(ids), minlength=3)
    assert counts.sum() == 3_000
    assert counts.min() > 700  # near-uniform thirds, not one hot range


def test_range_filter_agrees_with_routing():
    """The delta-bootstrap key_range mask and shard_of must carve the
    keyspace identically, or a rebalance streams the wrong rows."""
    rng = np.random.default_rng(11)
    keys = np.concatenate(
        [rng.integers(0, 2**62, 2_000), np.arange(200)]
    ).astype(np.int64)
    sm = ShardMap.even(list(MH_REGIONS), 5)
    coords = shard_coordinate(keys)
    sids = sm.shard_of(keys)
    for sid in range(sm.num_shards):
        lo, hi = sm.shard_range(sid)
        mask = (coords >= np.uint64(lo)) & (coords < np.uint64(hi))
        np.testing.assert_array_equal(mask, sids == sid, err_msg=f"shard {sid}")


def test_shard_ranges_tile_the_keyspace():
    sm = ShardMap.even(list(MH_REGIONS), 7)
    edges = [sm.shard_range(s) for s in range(sm.num_shards)]
    assert edges[0][0] == 0 and edges[-1][1] == KEY_SPACE
    for (_, hi), (lo, _) in zip(edges, edges[1:]):
        assert hi == lo


def test_negative_keys_rejected():
    sm = ShardMap.even(list(MH_REGIONS))
    with pytest.raises(ValueError, match="non-negative"):
        sm.shard_of(np.array([-1], np.int64))


# -- one facade over every store front ----------------------------------------


def test_store_fronts_satisfy_the_facade():
    from repro.core.featurestore import FeatureStore
    from repro.core.replication import GeoFeatureStore

    fs = FeatureStore("plain", region="r0", topology=mh_topo())
    geo = GeoFeatureStore("single-home", topology=mh_topo(), home_region="r0")
    mh = make_mh()
    for store in (fs, geo, mh):
        assert isinstance(store, StoreFacade), type(store).__name__


# -- gauge hygiene (the satellite bugfix) -------------------------------------


def test_clear_replica_gauges_is_shard_aware():
    """Per-shard lag gauges put the replica MID-PATH
    (``replication/shard_lag_batches/{replica}/{shard}``); eviction must
    clear those too, but only on full path segments — a replica named
    ``r1`` must not clear ``r11``'s gauges."""
    mon = HealthMonitor()
    mon.record_shard_lag("r1", 2, batches=5, rows=100)
    mon.record_shard_lag("r11", 2, batches=3, rows=60)
    mon.system.set_gauge("replication/lag_batches/r1", 5.0)
    mon.clear_replica_gauges("r1")
    gauges = mon.system.gauges
    assert not [
        k
        for k in gauges
        if k.startswith("replication/") and "r1" in k.split("/")
    ]
    assert gauges["replication/shard_lag_batches/r11/2"] == 3.0


# -- active-active convergence ------------------------------------------------


def test_concurrent_writes_at_every_home_converge():
    mh = make_mh()
    rng = np.random.default_rng(3)
    infos = write_everywhere(mh, rng)
    assert mh.pending_batches() > 0  # something actually replicated
    mh.converge()
    assert_mesh_identical(mh, "steady state")
    for info, region in zip(infos, mh.regions()):
        assert sum(info["slices"].values()) == info["rows"]
        assert info["forwarded_rows"] == info["rows"] - info["slices"].get(
            region, 0
        )
    wl = mh.write_log
    assert wl["rows"] == sum(i["rows"] for i in infos)
    assert wl["forwarded_rows"] == sum(i["forwarded_rows"] for i in infos)
    assert wl["local_rows"] == wl["rows"] - wl["forwarded_rows"]
    assert (
        mh.monitor.system.counters["multihome/forwarded_rows"]
        == wl["forwarded_rows"]
    )


def test_converged_mesh_is_echo_free():
    """After converge, further drains ship NOTHING: replica applies of
    foreign batches publish no echo into their own home's log."""
    mh = make_mh()
    rng = np.random.default_rng(4)
    write_everywhere(mh, rng)
    mh.converge()
    shipped = lambda: sum(
        ledger.batches
        for rep in mh.replicators.values()
        for ledger in rep.shipped.values()
    )
    before = shipped()
    for _ in range(3):
        mh.drain()
    assert mh.pending_batches() == 0
    assert shipped() == before
    assert mh.converge() == 0


def test_cross_shard_read_routes_in_sync_and_finds_all_rows():
    mh = make_mh()
    rng = np.random.default_rng(5)
    ids = np.arange(256, dtype=np.int64)
    frame = make_frame(rng, 256, 5_000, 10**6)
    frame.columns["entity_id"] = ids  # every queried id was written
    mh.write_batch("fs", 1, frame, region="r1", creation_ts=10**7)
    mh.converge()
    vals, found, route = mh.get_online_features(
        "fs", 1, [ids], consumer_region="r2"
    )
    assert found.all() and vals.shape == (256, 2)
    assert route["consumer"] == "r2"
    # every range serves from the in-sync consumer cell once converged
    assert {leg["region"] for leg in route["per_range"].values()} == {"r2"}
    assert route["modeled_ms"] == 1.0
    # a lagging consumer falls back to each range's HOME
    mh.write_batch("fs", 1, frame, region="r0", creation_ts=10**7 + 1)
    _, _, route = mh.get_online_features("fs", 1, [ids], consumer_region="r2")
    for sid, leg in route["per_range"].items():
        if sid not in mh.shard_map.owned_shards("r2"):
            assert leg["region"] == mh.shard_map.owner_of(sid)
    mh.converge()


def test_write_at_inactive_region_raises():
    mh = make_mh()
    rng = np.random.default_rng(6)
    with pytest.raises(RegionDownError, match="not an active home"):
        mh.write_batch(
            "fs", 1, make_frame(rng, 8, 100, 10**6), region="elsewhere"
        )


def test_failover_is_noop_while_everyone_is_healthy():
    assert make_mh().failover() is None


def test_per_shard_failover_moves_only_the_lost_range():
    mh = make_mh()
    rng = np.random.default_rng(8)
    write_everywhere(mh, rng)
    mh.converge()
    write_everywhere(mh, rng, base_ts=10**7 + 10)  # un-drained suffix
    owners_before = list(mh.shard_map.owners)
    victim = "r2"
    lost = mh.shard_map.owned_shards(victim)
    mh.mark_down(victim)
    info = mh.failover()
    assert info["shards"] == lost
    assert info["promoted"] in mh.regions()
    assert info["replayed_batches"] > 0  # the un-acked suffix replayed
    for sid, owner in enumerate(owners_before):
        expect = info["promoted"] if sid in lost else owner
        assert mh.shard_map.owner_of(sid) == expect
    assert victim not in mh.regions()
    mh.converge()
    assert_mesh_identical(mh, "post-failover")
    # the survivors still serve the WHOLE keyspace, writes keep flowing
    write_everywhere(mh, rng, base_ts=10**7 + 20)
    mh.converge()
    assert_mesh_identical(mh, "post-failover writes")
    ids = np.arange(64, dtype=np.int64)
    _, _, route = mh.get_online_features("fs", 1, [ids], consumer_region="r0")
    assert set(route["per_range"]) == set(range(mh.shard_map.num_shards))


def test_rejoin_comes_back_empty_then_rebalance_hands_a_range_back():
    mh = make_mh()
    rng = np.random.default_rng(9)
    write_everywhere(mh, rng)
    mh.converge()
    victim = "r2"
    lost = mh.shard_map.owned_shards(victim)
    mh.mark_down(victim)
    mh.failover()
    mh.converge()
    mh.mark_up(victim)
    back = mh.rejoin(victim)
    assert back["online_rows"] > 0 and back["offline_rows"] > 0
    assert mh.shard_map.owned_shards(victim) == []  # no ranges until handed
    mh.converge()
    assert_mesh_identical(mh, "post-rejoin")
    moved = mh.rebalance(lost[0], victim)
    assert moved["moved"] and mh.shard_map.owner_of(lost[0]) == victim
    write_everywhere(mh, rng, base_ts=10**7 + 30)  # incl. at the rejoined home
    mh.converge()
    assert_mesh_identical(mh, "post-rebalance writes")
    assert mh.monitor.system.counters["shards/rebalances"] == 1


def test_graceful_leave_rehomes_ranges_and_survivors_converge():
    mh = make_mh()
    rng = np.random.default_rng(10)
    write_everywhere(mh, rng)
    mh.converge()
    out = mh.leave_region("r2")
    assert out["left"] == "r2" and len(out["moves"]) == 1
    assert "r2" not in mh.shard_map.regions()
    assert mh.regions() == ["r0", "r1"]
    write_everywhere(mh, rng, base_ts=10**7 + 40)
    mh.converge()
    assert_mesh_identical(mh, "post-leave writes")
    with pytest.raises(ValueError, match="below two homes"):
        mh.leave_region("r1")


def test_rebalance_to_same_owner_is_a_noop():
    mh = make_mh()
    owner = mh.shard_map.owner_of(0)
    assert mh.rebalance(0, owner) == {
        "shard": 0,
        "from": owner,
        "to": owner,
        "moved": False,
    }
    assert mh.shard_map.version == 0
