"""Device-resident online store: host-mirror/device-truth protocol.

The contract under test (ISSUE 2 tentpole): device memory is the source of
truth for the kernel engine's planes; host numpy mirrors are lazy, dirty-
tracked, synced on demand, and invalidated across ``_grow``/``sweep``/engine
switches.  Stale-mirror reads are the main new failure mode, so every
host-facing consumer (``dump_all``, ``get_record``, host-path lookups, the
``vector``/``loop`` engines) is exercised against fresh kernel merges; and a
steady-state merge+lookup cycle must move O(batch) bytes host<->device, not
O(P·C·D).  Sweep slot recycling (the TTL-churn capacity leak fix) is covered
here too, across all three engines.
"""

import numpy as np
import pytest

from repro.core.assets import Entity, Feature, FeatureSetSpec, MaterializationSettings
from repro.core.dsl import UDFTransform
from repro.core.online_store import OnlineStore, o_batch_byte_budget
from repro.core.table import Table
from tests.core.test_merge_engine import assert_online_identical


def make_spec(ttl=None, n_feats=1):
    return FeatureSetSpec(
        name="fs",
        version=1,
        entity=Entity("cust", ("entity_id",)),
        features=tuple(Feature(f"f{i}") for i in range(n_feats)),
        source_name="src",
        transform=UDFTransform(lambda df, ctx: df, name="id"),
        materialization=MaterializationSettings(True, True, online_ttl=ttl),
    )


def make_frame(rng, n, id_hi, ev_hi, n_feats=1):
    cols = {
        "entity_id": rng.integers(0, id_hi, n).astype(np.int64),
        "ts": rng.integers(0, ev_hi, n).astype(np.int64),
    }
    for i in range(n_feats):
        cols[f"f{i}"] = rng.random(n).astype(np.float32)
    return Table(cols)


# -- TTL expiry parity: kernel (device) vs host lookup path -------------------


def test_ttl_expiry_parity_kernel_vs_host_lookup():
    """Same store, both GET paths, across the expiry boundary: byte-identical
    (values AND found), with the kernel path reading creation_ts from device
    truth rather than the host mirror."""
    spec = make_spec(ttl=100)
    store = OnlineStore(num_partitions=4, merge_engine="kernel")
    rng = np.random.default_rng(0)
    store.merge(spec, make_frame(rng, 60, 20, 50), 1_000)
    store.merge(spec, make_frame(rng, 60, 20, 80), 1_050)  # half re-stamped
    ids = [np.arange(25, dtype=np.int64)]
    # now=None skips TTL; then just-inside, boundary (not expired: > is
    # strict), and past-expiry for the older creation_ts cohort
    for now in (None, 1_060, 1_100, 1_120, 1_200):
        vk, fk = store.lookup("fs", 1, ids, now=now, use_kernel=True)
        vh, fh = store.lookup("fs", 1, ids, now=now, use_kernel=False)
        np.testing.assert_array_equal(fk, fh, err_msg=f"found @ now={now}")
        np.testing.assert_array_equal(vk, vh, err_msg=f"values @ now={now}")
    # fully expired: both paths agree on nothing found
    _, fk = store.lookup("fs", 1, ids, now=10_000, use_kernel=True)
    _, fh = store.lookup("fs", 1, ids, now=10_000, use_kernel=False)
    assert not fk.any() and not fh.any()


def test_ttl_parity_after_sweep_and_reinsert():
    spec = make_spec(ttl=50)
    store = OnlineStore(num_partitions=2, initial_capacity=8, merge_engine="kernel")
    rng = np.random.default_rng(1)
    store.merge(spec, make_frame(rng, 30, 10, 5), 100)
    store.sweep("fs", 1, now=200)  # everything expired + freed
    store.merge(spec, make_frame(rng, 30, 10, 5), 300)  # recycled slots
    ids = [np.arange(10, dtype=np.int64)]
    for now in (310, 349, 350, 351, 400):
        vk, fk = store.lookup("fs", 1, ids, now=now, use_kernel=True)
        vh, fh = store.lookup("fs", 1, ids, now=now, use_kernel=False)
        np.testing.assert_array_equal(fk, fh, err_msg=f"now={now}")
        np.testing.assert_array_equal(vk, vh, err_msg=f"now={now}")


# -- mirror invalidation across engine switches / grow / sweep / dump ---------


def test_engine_switch_sequences_stay_identical():
    """kernel -> vector -> kernel -> loop on ONE store: every switch crosses
    the device/host truth boundary (sync + drop on the way down, re-upload
    on the way up).  End state must match a pure-loop store."""
    spec = make_spec()
    mixed = OnlineStore(num_partitions=4, initial_capacity=8)
    ref = OnlineStore(num_partitions=4, initial_capacity=8, merge_engine="loop")
    rng = np.random.default_rng(2)
    frames = [make_frame(rng, 50, 30, 6) for _ in range(4)]
    for i, (f, engine) in enumerate(
        zip(frames, ("kernel", "vector", "kernel", "loop"))
    ):
        mixed.merge(spec, f, 1_000 + i, engine=engine)
        ref.merge(spec, f, 1_000 + i, engine="loop")
    assert_online_identical(mixed, ref, spec, "engine switching")


def test_host_reads_see_kernel_merges():
    """dump_all / get_record / host lookup immediately after kernel merges:
    the lazy mirror must sync, not serve stale planes."""
    spec = make_spec()
    store = OnlineStore(num_partitions=4, merge_engine="kernel")
    rng = np.random.default_rng(3)
    store.merge(spec, make_frame(rng, 40, 15, 10), 500)
    t = store._tables[spec.key]
    assert t.host_stale  # kernel merge advanced device truth
    # an override the stale mirror doesn't know about
    f = Table({
        "entity_id": np.array([3], np.int64),
        "ts": np.array([99], np.int64),
        "f0": np.array([7.5], np.float32),
    })
    store.merge(spec, f, 600)
    rec = store.get_record("fs", 1, [np.array([3])])[0]
    assert rec["event_ts"] == 99 and rec["features"][0] == 7.5
    assert not t.host_stale  # get_record synced
    store.merge(spec, f, 700)  # noop (same ev, but cr 700 > 600 -> override)
    dump = store.dump_all("fs", 1)
    i = int(np.searchsorted(dump["__key__"], 3))
    assert dump["creation_ts"][i] == 700
    v, fd = store.lookup("fs", 1, [np.array([3])], use_kernel=False)
    assert fd[0] and v[0, 0] == 7.5


def test_grow_mid_kernel_stream_identical():
    """Capacity doublings during kernel merges force sync+drop+reupload;
    state stays byte-identical to the loop reference."""
    spec = make_spec()
    k = OnlineStore(num_partitions=2, initial_capacity=4, merge_engine="kernel")
    l = OnlineStore(num_partitions=2, initial_capacity=4, merge_engine="loop")
    rng = np.random.default_rng(4)
    ids = rng.permutation(np.arange(300, dtype=np.int64))
    for lo in range(0, 300, 60):  # growth interleaved with merges
        f = Table({
            "entity_id": ids[lo:lo + 60],
            "ts": np.full(60, 5, np.int64),
            "f0": rng.random(60).astype(np.float32),
        })
        k.merge(spec, f, 1_000 + lo)
        l.merge(spec, f, 1_000 + lo)
    assert_online_identical(k, l, spec, "grow under kernel engine")
    assert k._tables[spec.key].keys_lo.shape[1] >= 256


def test_mirror_is_writable_after_kernel_merge():
    """Regression: the PR-1 kernel path left np views of device buffers as
    host planes — a later loop/vector merge on the same store would raise
    'assignment destination is read-only'.  The sync protocol must hand the
    host engines writable mirrors."""
    spec = make_spec()
    store = OnlineStore(num_partitions=2, merge_engine="kernel")
    rng = np.random.default_rng(5)
    store.merge(spec, make_frame(rng, 20, 8, 5), 100)
    store.merge(spec, make_frame(rng, 20, 8, 5), 200, engine="loop")  # must not raise
    store.merge(spec, make_frame(rng, 20, 8, 5), 300, engine="vector")
    for plane in ("event_ts", "creation_ts", "values"):
        assert getattr(store._tables[spec.key], plane).flags.writeable


# -- sweep slot recycling (TTL-churn capacity leak fix) -----------------------


@pytest.mark.parametrize("engine", ["loop", "vector", "kernel"])
def test_sweep_recycles_slots_capacity_bounded(engine):
    """Rolling TTL churn: every generation expires and is swept before the
    next insert wave.  With free-list recycling the partitions must never
    grow past their initial capacity (the pre-fix store doubled forever)."""
    spec = make_spec(ttl=10)
    store = OnlineStore(
        num_partitions=2, initial_capacity=64, merge_engine=engine
    )
    rng = np.random.default_rng(6)
    for gen in range(8):
        ids = (gen * 100 + np.arange(80)).astype(np.int64)  # fresh ids per gen
        f = Table({
            "entity_id": ids,
            "ts": np.full(80, gen, np.int64),
            "f0": rng.random(80).astype(np.float32),
        })
        now = gen * 100
        if gen:
            store.sweep("fs", 1, now=now)
        store.merge(spec, f, now + 1)
    t = store._tables[spec.key]
    assert t.keys_lo.shape[1] == 64, "TTL churn leaked capacity"
    assert store.num_records("fs", 1) == 80
    # fill is bounded by live records + transient imbalance, never cumulative
    assert int(t.fill.sum()) <= 128


def test_sweep_recycling_parity_across_engines():
    """Sweep-heavy interleavings with partial expiry: all engines assign
    recycled slots identically (free lists are part of the compared state)."""
    spec = make_spec(ttl=40)
    stores = {
        e: OnlineStore(num_partitions=4, initial_capacity=8, merge_engine=e)
        for e in ("loop", "vector", "kernel")
    }
    rng = np.random.default_rng(7)
    for step in range(6):
        frame = make_frame(rng, 30, 25, 5)
        now = 100 + step * 30
        for store in stores.values():
            if step % 2:
                store.sweep("fs", 1, now=now)
            store.merge(spec, frame, now)
    assert_online_identical(stores["loop"], stores["vector"], spec, "sweep/vector")
    assert_online_identical(stores["loop"], stores["kernel"], spec, "sweep/kernel")


# -- transfer accounting: steady state is O(batch) ----------------------------


def test_steady_state_cycle_moves_o_batch_bytes():
    """After warmup, a kernel merge+lookup cycle must not re-upload or pull
    the (P, C, D) planes: zero device uploads, zero host syncs, and per-cycle
    bytes bounded by a small multiple of the batch footprint — far below the
    table footprint."""
    spec = make_spec(ttl=None, n_feats=4)
    store = OnlineStore(
        num_partitions=8, initial_capacity=256, merge_engine="kernel"
    )
    rng = np.random.default_rng(8)
    store.merge(spec, make_frame(rng, 20_000, 5_000, 100, n_feats=4), 10**6)
    batch = 512
    ids = [rng.integers(0, 5_000, batch).astype(np.int64)]
    # warm both jitted paths at the steady batch shapes
    store.merge(spec, make_frame(rng, batch, 5_000, 200, n_feats=4), 2 * 10**6)
    store.lookup("fs", 1, ids)
    store.reset_transfer_stats()

    cycles = 10
    for i in range(cycles):
        store.merge(
            spec, make_frame(rng, batch, 5_000, 300 + i, n_feats=4),
            3 * 10**6 + i,
        )
        store.lookup("fs", 1, ids)
    tx = store.transfer_stats()
    assert tx["device_uploads"] == 0, "steady-state merge re-uploaded the table"
    assert tx["host_syncs"] == 0, "steady-state cycle pulled the host mirror"

    table_bytes = store.device_state("fs", 1).nbytes()
    per_cycle = (tx["h2d_bytes"] + tx["d2h_bytes"]) / cycles
    record_bytes = 8 * 4 + 4 * 4  # id/ts planes + 4 f32 features
    assert per_cycle <= o_batch_byte_budget(batch, record_bytes), (
        f"per-cycle traffic {per_cycle} not O(batch)"
    )
    assert per_cycle < table_bytes / 4, (
        f"per-cycle traffic {per_cycle} is table-sized ({table_bytes})"
    )


def test_transfer_ledger_counts_uploads_and_syncs():
    spec = make_spec()
    store = OnlineStore(num_partitions=2, merge_engine="kernel")
    rng = np.random.default_rng(9)
    store.merge(spec, make_frame(rng, 50, 20, 5), 100)
    tx = store.transfer_stats()
    assert tx["device_uploads"] >= 1 and tx["h2d_bytes"] > 0
    assert tx["host_syncs"] == 0
    store.dump_all("fs", 1)  # forces one mirror sync
    assert store.transfer_stats()["host_syncs"] == 1
    store.dump_all("fs", 1)  # mirror clean: no second pull
    assert store.transfer_stats()["host_syncs"] == 1
