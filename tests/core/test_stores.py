"""Storage-scheme semantics (paper §4.5): Algorithm 2 + the Fig. 5 example."""

import numpy as np
import pytest

from repro.core.assets import Entity, Feature, FeatureSetSpec, MaterializationSettings
from repro.core.dsl import UDFTransform
from repro.core.offline_store import CREATION_TS, EVENT_TS, OfflineStore
from repro.core.online_store import OnlineStore
from repro.core.table import Table


def make_spec(name="fs", version=1, ttl=None):
    return FeatureSetSpec(
        name=name,
        version=version,
        entity=Entity("cust", ("entity_id",)),
        features=(Feature("f0"),),
        source_name="src",
        transform=UDFTransform(lambda df, ctx: df, name="id"),
        materialization=MaterializationSettings(True, True, online_ttl=ttl),
    )


def frame(ids, ts, vals):
    return Table(
        {
            "entity_id": np.asarray(ids, np.int64),
            "ts": np.asarray(ts, np.int64),
            "f0": np.asarray(vals, np.float32),
        }
    )


class TestPaperFig5Example:
    """R0..R3 with event/creation timestamps; offline keeps all, online keeps
    max(tuple(event_ts, creation_ts))."""

    def setup_method(self):
        self.spec = make_spec()
        self.offline = OfflineStore(num_shards=2)
        self.online = OnlineStore(num_partitions=4)
        # one entity; event t0<t1<t2; creation t0'<t1'<t2'<t3'
        self.t = {"t0": 100, "t1": 200, "t2": 300}
        self.c = {"t0p": 150, "t1p": 250, "t2p": 350, "t3p": 450}

    def _merge(self, ev, cr, val):
        f = frame([7], [ev], [val])
        self.offline.merge(self.spec, f, cr)
        self.online.merge(self.spec, f, cr)

    def test_paper_fig5_example(self):
        # T1: after R0, R1, R2 materialized
        self._merge(self.t["t0"], self.c["t0p"], 0.0)  # R0
        self._merge(self.t["t1"], self.c["t1p"], 1.0)  # R1
        self._merge(self.t["t2"], self.c["t2p"], 2.0)  # R2
        assert self.offline.num_rows("fs", 1) == 3
        rec = self.online.get_record("fs", 1, [np.array([7])])[0]
        assert rec[EVENT_TS] == self.t["t2"] and rec["features"][0] == 2.0

        # T2: R3 = late re-materialization of event t1 with creation t3'
        self._merge(self.t["t1"], self.c["t3p"], 3.0)  # R3
        assert self.offline.num_rows("fs", 1) == 4  # offline keeps ALL 4
        rec = self.online.get_record("fs", 1, [np.array([7])])[0]
        # online still holds R2: R3.event_ts < R2.event_ts
        assert rec[EVENT_TS] == self.t["t2"] and rec["features"][0] == 2.0
        assert self.online.num_records("fs", 1) == 1


class TestAlgorithm2Offline:
    def test_insert_iff_key_absent(self):
        spec, store = make_spec(), OfflineStore(num_shards=2)
        f = frame([1, 2], [10, 20], [1.0, 2.0])
        assert store.merge(spec, f, 100) == 2
        # identical merge (same creation_ts): full no-op — retry safety
        assert store.merge(spec, f, 100) == 0
        assert store.num_rows("fs", 1) == 2
        # same (id, event_ts) but NEW creation_ts: new record (history kept)
        assert store.merge(spec, f, 200) == 2
        assert store.num_rows("fs", 1) == 4

    def test_creation_after_event_enforced(self):
        spec, store = make_spec(), OfflineStore()
        with pytest.raises(ValueError, match="creation_timestamp"):
            store.merge(spec, frame([1], [500], [1.0]), 400)


class TestAlgorithm2Online:
    def setup_method(self):
        self.spec = make_spec()
        self.store = OnlineStore(num_partitions=2, initial_capacity=8)

    def rec(self):
        return self.store.get_record("fs", 1, [np.array([5])])[0]

    def test_all_branches(self):
        # insert (key absent)
        self.store.merge(self.spec, frame([5], [100], [1.0]), 150)
        assert self.rec()[EVENT_TS] == 100
        # override: newer event_ts
        self.store.merge(self.spec, frame([5], [200], [2.0]), 250)
        assert self.rec()[EVENT_TS] == 200 and self.rec()["features"][0] == 2.0
        # no-op: older event_ts
        self.store.merge(self.spec, frame([5], [100], [9.0]), 300)
        assert self.rec()["features"][0] == 2.0
        # override: same event_ts, newer creation_ts
        self.store.merge(self.spec, frame([5], [200], [3.0]), 400)
        assert self.rec()["features"][0] == 3.0 and self.rec()[CREATION_TS] == 400
        # no-op: same event_ts, older creation_ts
        self.store.merge(self.spec, frame([5], [200], [8.0]), 350)
        assert self.rec()["features"][0] == 3.0
        assert self.store.noops == 2 and self.store.overrides == 2

    def test_growth(self):
        ids = np.arange(100, dtype=np.int64)
        self.store.merge(self.spec, frame(ids, [100] * 100, ids.astype(float)), 200)
        assert self.store.num_records("fs", 1) == 100
        vals, found = self.store.lookup("fs", 1, [ids], use_kernel=False)
        assert found.all() and np.allclose(vals[:, 0], ids)

    def test_ttl(self):
        spec = make_spec(ttl=1000)
        store = OnlineStore(num_partitions=2)
        store.merge(spec, frame([1], [100], [1.0]), 200)
        _, found = store.lookup("fs", 1, [np.array([1])], now=900, use_kernel=False)
        assert found[0]
        _, found = store.lookup("fs", 1, [np.array([1])], now=1500, use_kernel=False)
        assert not found[0]  # expired: creation 200 + ttl 1000 < 1500
        assert store.sweep("fs", 1, now=1500) == 1
        assert store.num_records("fs", 1) == 0


def test_latest_per_key_matches_tuple_max():
    spec, store = make_spec(), OfflineStore(num_shards=3)
    rng = np.random.default_rng(0)
    for cr in [1000, 2000, 3000]:
        ids = rng.integers(0, 20, 30)
        ts = rng.integers(0, 900, 30)
        store.merge(spec, frame(ids, ts, ts.astype(float)), cr)
    latest = store.latest_per_key("fs", 1)
    hist = store.read("fs", 1)
    for i in range(len(latest)):
        k = latest["__key__"][i]
        mask = hist["__key__"] == k
        pairs = list(zip(hist[EVENT_TS][mask], hist[CREATION_TS][mask]))
        assert (latest[EVENT_TS][i], latest[CREATION_TS][i]) == max(pairs)
