"""§4.6 feature-model lineage: scale + cross-region queries."""


from repro.core.lineage import LineageGraph, ModelNode


def test_hundreds_of_features_per_model():
    """The paper's scalability challenge: 'a model can use hundreds or more
    features'."""
    g = LineageGraph()
    m = ModelNode("big", 1, "eastus")
    refs = [f"fs{i % 20}:v1:f{i}" for i in range(800)]
    g.register_model(m, refs)
    assert len(g.features_of_model(m)) == 800
    # reverse queries are O(degree), and exact
    assert g.models_of_feature("fs3:v1:f3") == {m}
    assert g.models_of_feature("nope:v1:x") == set()


def test_cross_region_lineage_and_global_view():
    g = LineageGraph()
    for i, region in enumerate(["eastus", "westus2", "westeurope", "eastus"]):
        g.register_model(
            ModelNode(f"m{i}", 1, region), [f"act:v1:s2", f"act:v1:c{i}"]
        )
    by_region = g.models_by_region("act:v1:s2")
    assert by_region == {"eastus": 2, "westus2": 1, "westeurope": 1}
    view = g.global_view()
    assert view["num_models"] == 4
    assert view["models_per_region"]["eastus"] == 2


def test_impact_of_feature_set_blast_radius():
    g = LineageGraph()
    a = ModelNode("a", 1, "eastus")
    b = ModelNode("b", 2, "westus2")
    g.register_model(a, ["act:v1:s2"])
    g.register_model(b, ["act:v2:s2", "other:v1:x"])
    assert g.impact_of_feature_set("act", 1) == {a}
    assert g.impact_of_feature_set("act", 2) == {b}
    assert g.impact_of_feature_set("other", 1) == {b}


def test_scale_10k_models():
    """Registration + queries stay fast at 10k models x 50 features."""
    import time

    g = LineageGraph()
    t0 = time.perf_counter()
    for i in range(10_000):
        g.register_model(
            ModelNode(f"m{i}", 1, ["eastus", "westus2"][i % 2]),
            [f"fs{j}:v1:f{j}" for j in range(i % 50, i % 50 + 10)],
        )
    reg_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    _ = g.models_of_feature("fs25:v1:f25")
    q_s = time.perf_counter() - t0
    assert reg_s < 10.0 and q_s < 0.1
