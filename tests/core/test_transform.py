"""Algorithm 1 (feature calculation flow) — unit tests + properties."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.assets import Entity, Feature, FeatureSetSpec, MaterializationSettings
from repro.core.dsl import DslTransform, RollingAgg, UDFTransform
from repro.core.table import Table
from repro.core.transform import FeatureWindow, compute_feature_window
from repro.data.sources import SyntheticEventSource

HOUR = 3_600_000


def _spec(lookback=2 * HOUR, transform=None):
    return FeatureSetSpec(
        name="act", version=1,
        entity=Entity("customer", ("entity_id",)),
        features=(Feature("s2", "float32"),),
        source_name="tx",
        transform=transform or DslTransform(
            "entity_id", "ts", [RollingAgg("s2", "amount", 2 * HOUR, "sum")]
        ),
        timestamp_col="ts", source_lookback=lookback,
        materialization=MaterializationSettings(
            offline_enabled=True, online_enabled=False, schedule_interval=HOUR
        ),
    )


def test_window_validation():
    with pytest.raises(ValueError):
        FeatureWindow(5, 5)
    assert FeatureWindow(0, 2).overlaps(FeatureWindow(1, 3))
    assert not FeatureWindow(0, 2).overlaps(FeatureWindow(2, 4))  # half-open


def test_source_binding_enforced():
    src = SyntheticEventSource("other")
    with pytest.raises(ValueError):
        compute_feature_window(_spec(), src, FeatureWindow(0, HOUR))


def test_output_clipped_to_feature_window():
    src = SyntheticEventSource("tx", num_entities=8, events_per_bucket=40)
    frame = compute_feature_window(_spec(), src, FeatureWindow(3 * HOUR, 5 * HOUR))
    assert len(frame) > 0
    assert frame["ts"].min() >= 3 * HOUR
    assert frame["ts"].max() < 5 * HOUR


def test_lookback_affects_values_not_rows():
    """Rows are identical with/without lookback; VALUES differ because the
    rolling window sees pre-window history (the whole point of
    source_lookback in Algorithm 1)."""
    src = SyntheticEventSource("tx", num_entities=4, events_per_bucket=60)
    w = FeatureWindow(3 * HOUR, 4 * HOUR)
    with_lb = compute_feature_window(_spec(lookback=2 * HOUR), src, w)
    no_lb = compute_feature_window(_spec(lookback=0), src, w)
    assert len(with_lb) == len(no_lb)
    np.testing.assert_array_equal(with_lb["ts"], no_lb["ts"])
    # some window near the start of the feature window must differ
    assert not np.allclose(with_lb["s2"], no_lb["s2"])
    # and with-lookback sums are always >= the truncated ones
    assert (with_lb["s2"] >= no_lb["s2"] - 1e-3).all()


def test_udf_black_box_path():
    def udf(df: Table, ctx) -> Table:
        return Table({
            "entity_id": df["entity_id"],
            "ts": df["ts"],
            "s2": (df["amount"] * 2).astype(np.float32),
        })

    src = SyntheticEventSource("tx", num_entities=4, events_per_bucket=30)
    frame = compute_feature_window(
        _spec(transform=UDFTransform(udf)), src, FeatureWindow(0, 2 * HOUR)
    )
    raw = src.read(0, 2 * HOUR)
    np.testing.assert_allclose(np.sort(frame["s2"]), np.sort(raw["amount"] * 2))


def test_schema_validation_rejects_missing_columns():
    def bad_udf(df, ctx):
        return Table({"entity_id": df["entity_id"], "ts": df["ts"]})  # no s2

    src = SyntheticEventSource("tx")
    with pytest.raises(Exception):
        compute_feature_window(
            _spec(transform=UDFTransform(bad_udf)), src, FeatureWindow(0, HOUR)
        )


@settings(max_examples=15, deadline=None)
@given(
    start_h=st.integers(0, 20),
    len_h=st.integers(1, 6),
    lookback_h=st.integers(0, 4),
)
def test_determinism_property(start_h, len_h, lookback_h):
    """Same (source, spec, window) -> identical frame, regardless of what
    other windows were computed before (retry/idempotence foundation)."""
    src = SyntheticEventSource("tx", num_entities=6, events_per_bucket=25)
    spec = _spec(lookback=lookback_h * HOUR)
    w = FeatureWindow(start_h * HOUR, (start_h + len_h) * HOUR)
    a = compute_feature_window(spec, src, w)
    _ = compute_feature_window(spec, src, FeatureWindow(0, HOUR))  # interleave
    b = compute_feature_window(spec, src, w)
    assert len(a) == len(b)
    np.testing.assert_array_equal(a["ts"], b["ts"])
    np.testing.assert_array_equal(a["s2"], b["s2"])
