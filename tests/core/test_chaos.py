"""Chaos convergence suite (ISSUE 7): seeded faults, detected failure.

The tentpole claims under test:

  * CONVERGENCE — under every seeded fault schedule (drop / duplicate /
    reorder / corrupt / partition at >= 10% rates, both planes), repeated
    draining converges every replica byte-identical online and
    chunk-set-identical offline, and no un-acked batch is ever truncated;
  * DETECTION — a partitioned replica walks HEALTHY -> SUSPECT -> DEAD on
    consecutive delivery failures, which drives ``topology.mark_down`` so
    read routing avoids it WITHOUT any manual flip, and probe-based
    recovery (or eviction + auto-rejoin delta bootstrap) brings it back;
  * DETERMINISM — the whole fault schedule and the state machine's
    reaction to it are a pure function of the plan seed: identical runs
    produce identical retry/timeout/fault counters (what lets the chaos
    bench gate those counters EXACTLY in CI);
  * IDEMPOTENCE — redelivering any prefix/suffix of the frames a replica
    already applied (both planes, including bootstrap ``seq=-1`` frames)
    leaves its state bit-identical — at-least-once delivery, exactly-once
    effect;
  * ACCOUNTING — a replica-side apply error mid-frame still records the
    applied prefix in the shipping ledger and keeps its acks (the
    partial-frame regression from the v1 ``_ship_frame``).
"""

import numpy as np
import pytest

from repro.core import wire
from repro.core.channel import (
    Delivery,
    FaultPlan,
    FaultyChannel,
    InProcessChannel,
)
from repro.core.online_store import OnlineStore
from repro.core.regions import GeoTopology, Region, RegionDownError
from repro.core.replication import DeliveryPolicy, GeoReplicator
from tests.core.test_replication import (
    HOUR,
    assert_dumps_identical,
    assert_planes_identical,
    geo_store,
    make_frame,
    make_spec,
    topo,
)

#: tight thresholds so chaos tests converge in few drain rounds
FAST_POLICY = DeliveryPolicy(
    suspect_after=2,
    dead_after=4,
    backoff_base=1,
    backoff_cap=2,
    probe_interval=1,
)


class ScriptedChannel(InProcessChannel):
    """Perfect channel with a switch: while ``down``, every transmit is
    dropped — deterministic outage scripting for state-machine tests."""

    def __init__(self, topology: GeoTopology) -> None:
        super().__init__(topology)
        self.down = False

    def transmit(self, src, dst, frame) -> Delivery:
        if self.down:
            return Delivery(arrivals=(), latency_ms=0.0, faults=("drop",))
        return super().transmit(src, dst, frame)


class RecordingChannel(InProcessChannel):
    """Perfect channel that records every (dst, frame bytes) it carried —
    the redelivery corpus for the idempotence property tests."""

    def __init__(self, topology: GeoTopology) -> None:
        super().__init__(topology)
        self.sent: list[tuple[str, bytes]] = []

    def transmit(self, src, dst, frame) -> Delivery:
        self.sent.append((dst, frame.data))
        return super().transmit(src, dst, frame)


def drive(g, *, ticks=6, start=1):
    for i in range(start, start + ticks):
        g.tick(i * HOUR)
        g.drain()


def converge(g, *, rounds=300):
    """Drain until every replica's cursor reaches the head (and nothing is
    evicted); fail the test if the schedule never lets it converge."""
    rep = g.replicator
    for n in range(rounds):
        g.drain()
        done = all(rep.log.pending_count(r) == 0 for r in rep.replica_regions())
        if done and not g.evicted:
            return n + 1
    pytest.fail(f"replicas did not converge within {rounds} drain rounds")


def spec_of(g):
    return g.fs.registry.get_feature_set("act", 1)


# -- the seeded fault matrix (CI chaos smoke runs this) ------------------------


@pytest.mark.parametrize("seed", [101, 202, 303])
@pytest.mark.parametrize(
    "kind,counter",
    [
        ("drop_rate", "dropped"),
        ("dup_rate", "duplicated"),
        ("reorder_rate", "reordered"),
        ("corrupt_rate", "corrupted"),
    ],
)
def test_chaos_matrix(seed, kind, counter):
    """Each fault kind alone, at 25%, for three seeds: both planes of both
    replicas converge to the home stores, and the fault actually fired."""
    t = topo()
    channel = FaultyChannel(FaultPlan(seed=seed, **{kind: 0.25}), t)
    g = geo_store(
        topology=t,
        channel=channel,
        delivery_policy=FAST_POLICY,
        replica_regions=("near", "far"),
    )
    drive(g, ticks=8)
    converge(g)
    assert channel.counts[counter] > 0, "schedule never injected the fault"
    for region in ("near", "far"):
        assert_planes_identical(g, region, spec_of(g), f"{kind} seed={seed}")


def test_chaos_mixed_faults_converge_and_count():
    """Everything at once — drop, dup, reorder, corrupt, ack loss, latency
    spikes — still converges, and the delivery ledger saw real retries,
    timeouts, CRC rejections, and absorbed redeliveries."""
    t = topo()
    plan = FaultPlan(
        seed=777,
        drop_rate=0.10,
        dup_rate=0.05,
        reorder_rate=0.05,
        corrupt_rate=0.05,
        ack_loss_rate=0.05,
        spike_rate=0.03,
    )
    channel = FaultyChannel(plan, t)
    g = geo_store(
        topology=t,
        channel=channel,
        delivery_policy=FAST_POLICY,
        replica_regions=("near", "far"),
    )
    drive(g, ticks=8)
    converge(g)
    for region in ("near", "far"):
        assert_planes_identical(g, region, spec_of(g), f"mixed chaos {region}")
    states = g.replicator.delivery
    totals = {
        k: sum(getattr(states[r], k) for r in states)
        for k in ("retries", "timeouts", "corrupt_frames", "redelivered_batches")
    }
    assert totals["retries"] > 0
    assert totals["timeouts"] > 0
    assert totals["corrupt_frames"] > 0
    assert totals["redelivered_batches"] > 0
    mon = g.fs.monitor.system.counters
    assert mon["replication/retries"] == totals["retries"]
    assert mon["replication/timeout"] == totals["timeouts"]
    assert mon["replication/corrupt_frame"] == totals["corrupt_frames"]
    assert mon["replication/redelivered"] == totals["redelivered_batches"]


def test_chaos_is_deterministic_per_seed():
    """Two identical runs over the same plan replay the same faults and the
    same state-machine reaction, counter for counter — the property that
    lets CI gate chaos retry counts exactly."""

    def run():
        t = topo()
        channel = FaultyChannel(
            FaultPlan(seed=42, drop_rate=0.15, dup_rate=0.08, corrupt_rate=0.08), t
        )
        g = geo_store(
            topology=t,
            channel=channel,
            delivery_policy=FAST_POLICY,
            replica_regions=("near", "far"),
        )
        drive(g)
        rounds = converge(g)
        states = g.replicator.delivery
        return (
            rounds,
            dict(channel.counts),
            {
                r: (st.retries, st.timeouts, st.corrupt_frames, st.transitions)
                for r, st in states.items()
            },
        )

    assert run() == run()


# -- detected failure: partition -> SUSPECT -> DEAD -> recovery ----------------


def test_partition_walks_suspect_dead_and_auto_recovers():
    """A partition window on one link drives the full detection arc with NO
    manual mark_down: SUSPECT after 2 straight failures, DEAD after 4
    (routing now avoids the region), probes fire on the probe schedule,
    and the first probe through the healed link flips the region back up
    and drains it to convergence."""
    t = topo()
    # events 0..7 to "near" are lost; everything else is perfect
    channel = FaultyChannel(FaultPlan(seed=1, partitions=(("near", 0, 8),)), t)
    g = geo_store(
        topology=t,
        channel=channel,
        delivery_policy=FAST_POLICY,
        replica_regions=("near", "far"),
    )
    g.tick(HOUR)
    st = g.replicator.delivery["near"]
    seen = set()
    for _ in range(30):
        g.drain()
        seen.add(st.status)
        if st.status == "dead":
            break
    assert seen == {"healthy", "suspect", "dead"}
    assert [(a, b) for _, a, b in st.transitions] == [
        ("healthy", "suspect"),
        ("suspect", "dead"),
    ]
    # DETECTED death marked the region down: routing avoids it
    assert t.regions["near"].healthy is False
    serving, _ = g.route_read("near")
    assert serving != "near"
    # the far replica was never disturbed
    assert g.replicator.delivery["far"].status == "healthy"
    # heal: probes keep firing on the schedule until one crosses the window
    g.tick(2 * HOUR)
    converge(g)
    assert st.status == "healthy"
    assert t.regions["near"].healthy is True
    assert ("dead", "healthy") in [(a, b) for _, a, b in st.transitions]
    for region in ("near", "far"):
        assert_planes_identical(g, region, spec_of(g), "post-partition")
    # recovered and in sync: local reads serve locally again
    serving, _ = g.route_read("near")
    assert serving == "near"


def test_long_partition_evicts_then_auto_rejoins_via_bootstrap():
    """Past ``evict_after`` failures the replica is torn out entirely (its
    cursor no longer pins the log); when the link heals, the next
    all-region drain re-probes it and re-admits it through the full
    delta-bootstrap rejoin — automatically."""
    t = topo()
    channel = FaultyChannel(FaultPlan(seed=2, partitions=(("near", 0, 9),)), t)
    policy = DeliveryPolicy(
        suspect_after=1,
        dead_after=2,
        backoff_base=1,
        backoff_cap=1,
        probe_interval=1,
        evict_after=5,
    )
    g = geo_store(
        topology=t,
        channel=channel,
        delivery_policy=policy,
        replica_regions=("near", "far"),
    )
    g.tick(HOUR)
    for _ in range(10):
        g.drain()
        if "near" in g.evicted:
            break
    assert "near" in g.evicted
    assert "near" not in g.replicator.stores
    assert "near" not in g.replicator.delivery
    assert "near" not in g.placement.replicas
    assert g.fs.monitor.system.counters["replication/evictions"] == 1
    # while evicted, the log no longer retains batches for it
    with pytest.raises(KeyError):
        g.replicator.log.pending("near")
    g.tick(2 * HOUR)  # more data lands while the region is out
    converge(g)  # auto-rejoin probes run inside the all-region drains
    assert "near" not in g.evicted
    assert "near" in g.replicator.stores
    assert g.last_bootstrap is not None and g.last_bootstrap["chunks"] > 0
    for region in ("near", "far"):
        assert_planes_identical(g, region, spec_of(g), "post-eviction rejoin")
    assert t.regions["near"].healthy is True


def test_detected_death_feeds_failover():
    """When the DEAD region is the one a consumer depends on, the standing
    failover path composes with detection: kill the link to every replica,
    mark the home down, and the promoted replica is byte-identical."""
    t = topo()
    channel = ScriptedChannel(t)
    g = geo_store(
        topology=t,
        channel=channel,
        delivery_policy=FAST_POLICY,
        replica_regions=("near",),
    )
    drive(g, ticks=3)
    spec = spec_of(g)
    g.tick(4 * HOUR)  # an un-drained suffix is pending at failure time
    home_dump = g.fs.online.dump_all(spec.name, spec.version)
    # the home region dies (operator signal); promotion replays the pending
    # suffix over the still-working channel
    g.mark_down("home")
    got = g.failover()
    assert got["promoted"] == "near"
    db = g.fs.online.dump_all(spec.name, spec.version)
    assert set(home_dump.names) == set(db.names)
    for name in home_dump.names:
        np.testing.assert_array_equal(home_dump[name], db[name], err_msg=name)


def test_promotion_replay_pushes_through_faults_or_raises():
    """Promotion replay retries forced drains through a flaky channel; if
    the channel never delivers, it raises DeliveryError rather than
    promoting a replica that silently lost acked-elsewhere batches."""
    from repro.core.channel import DeliveryError

    t = topo()
    channel = ScriptedChannel(t)
    g = geo_store(
        topology=t,
        channel=channel,
        delivery_policy=FAST_POLICY,
        replica_regions=("near",),
    )
    drive(g, ticks=2)
    g.tick(3 * HOUR)  # pending suffix exists
    channel.down = True
    g.mark_down("home")
    with pytest.raises(DeliveryError, match="promotion replay"):
        g.failover()


# -- state machine units -------------------------------------------------------


def test_backoff_schedule_is_capped_and_deterministic():
    """Consecutive failures back off exponentially (capped) with
    deterministic jitter: two identical runs produce the identical
    tick-by-tick trace, and backoff defers most drains (failures << drains)."""

    def run():
        t = topo()
        channel = ScriptedChannel(t)
        g = geo_store(
            topology=t,
            channel=channel,
            delivery_policy=DeliveryPolicy(
                suspect_after=2,
                dead_after=4,
                backoff_base=1,
                backoff_cap=4,
                probe_interval=3,
            ),
            replica_regions=("near",),
        )
        g.tick(HOUR)
        channel.down = True
        st = g.replicator.delivery["near"]
        trace = []
        for _ in range(40):
            g.drain("near")
            trace.append(
                (st.tick, st.status, st.consecutive_failures, st.backoff_until)
            )
        return g, channel, st, trace

    g1, _, st1, trace1 = run()
    g2, _, st2, trace2 = run()
    assert trace1 == trace2
    assert st1.transitions == st2.transitions
    assert [(a, b) for _, a, b in st1.transitions] == [
        ("healthy", "suspect"),
        ("suspect", "dead"),
    ]
    # backoff + probe cadence means only a fraction of drains transmitted
    assert st1.consecutive_failures < 40
    assert st1.probes > 0
    assert g1.topology.regions["near"].healthy is False
    # gauges track the walk
    gauges = g1.fs.monitor.system.gauges
    assert gauges["replication/state/near"] == 2.0


def test_recovery_resets_failure_streak_and_backoff():
    t = topo()
    channel = ScriptedChannel(t)
    g = geo_store(
        topology=t,
        channel=channel,
        delivery_policy=FAST_POLICY,
        replica_regions=("near",),
    )
    g.tick(HOUR)
    channel.down = True
    st = g.replicator.delivery["near"]
    for _ in range(12):
        g.drain("near")
    assert st.status == "dead" and st.consecutive_failures >= 4
    channel.down = False
    for _ in range(6):
        g.drain("near")
    assert st.status == "healthy"
    assert st.consecutive_failures == 0
    assert st.backoff_until <= st.tick
    assert g.replicator.log.pending_count("near") == 0
    assert g.fs.monitor.system.gauges["replication/state/near"] == 0.0
    assert_planes_identical(g, "near", spec_of(g), "post-outage catch-up")


# -- redelivery idempotence (satellite: at-least-once, exactly-once effect) ----


def test_replaying_any_prefix_or_suffix_of_shipped_frames_is_a_noop():
    """Record every frame a replica ever received — bootstrap ``seq=-1``
    chunks included — then redeliver arbitrary prefixes/suffixes (and the
    whole corpus, reversed) straight into the apply path: replica state
    must not move by a byte on either plane."""
    t = topo()
    channel = RecordingChannel(t)
    g = geo_store(topology=t, channel=channel, delivery_policy=FAST_POLICY)
    drive(g, ticks=3)  # home accumulates data first ...
    g.add_replica("near")  # ... so add_replica streams real bootstrap chunks
    drive(g, ticks=3, start=4)
    converge(g)
    spec = spec_of(g)
    assert_planes_identical(g, "near", spec, "pre-replay baseline")
    payloads = [data for dst, data in channel.sent if dst == "near"]
    corpus = [wire.decode_frame(data) for data in payloads]
    assert any(b.seq == wire.BOOTSTRAP_SEQ for f in corpus for b in f)
    assert any(b.plane == "offline" for f in corpus for b in f)
    assert any(b.plane == "online" for f in corpus for b in f)
    rep = g.replicator
    n = len(corpus)
    slices = [corpus[: n // 3], corpus[n // 2 :], corpus[::-1], corpus]
    for i, frames in enumerate(slices):
        for batches in frames:
            for batch in batches:
                rep._apply_decoded("near", batch)
        assert_planes_identical(g, "near", spec, f"replay slice {i}")


def test_faulty_redelivery_never_double_acks():
    """Under duplication + ack loss, acked batches arrive again and again;
    the per-seq dedup counts them and the cursor math never regresses or
    over-advances (pending_count stays exact)."""
    t = topo()
    channel = FaultyChannel(
        FaultPlan(seed=9, dup_rate=0.30, ack_loss_rate=0.20), t
    )
    g = geo_store(
        topology=t,
        channel=channel,
        delivery_policy=FAST_POLICY,
        replica_regions=("near",),
    )
    drive(g, ticks=5)
    converge(g)
    rep = g.replicator
    st = rep.delivery["near"]
    assert st.redelivered_batches > 0
    assert rep.log.pending_count("near") == 0
    assert rep.log.cursors["near"] == rep.log.next_seq
    assert_planes_identical(g, "near", spec_of(g), "dup/ack-loss chaos")


# -- exception-safe partial-frame accounting (satellite regression) ------------


def test_partial_frame_apply_failure_keeps_prefix_acks_and_ledger():
    """A replica-side apply error on batch 2 of a 3-batch coalesced frame:
    batch 1's ack and ledger entry survive, the error propagates loudly,
    and a later drain completes the frame to byte-identical state."""
    spec = make_spec()
    t = GeoTopology(
        regions={"h": Region("h"), "r": Region("r")},
        cross_region_latency_ms=40.0,
    )
    home = OnlineStore(num_partitions=4)
    repl = GeoReplicator(home, topology=t, home_region="h")
    replica = OnlineStore(num_partitions=4)
    repl.add_replica("r", replica)
    rng = np.random.default_rng(5)
    for i in range(3):
        home.merge(spec, make_frame(rng, 50, 20, 30 * (i + 1)), 1_000 + i)
    real = replica.merge_reduced
    calls = {"n": 0}

    def flaky(*a, **kw):
        calls["n"] += 1
        if calls["n"] == 2:
            raise RuntimeError("replica store exploded mid-frame")
        return real(*a, **kw)

    replica.merge_reduced = flaky
    with pytest.raises(RuntimeError, match="mid-frame"):
        repl.drain("r")
    ship = repl.shipped["r"]
    assert ship.frames == 1
    assert ship.batches == 1  # ONLY the applied prefix — not 0, not 3
    assert ship.rows > 0
    assert ship.bytes > 0  # the transmit itself was charged
    assert repl.log.is_acked("r", 0)
    assert not repl.log.is_acked("r", 1)
    assert repl.log.cursors["r"] == 1
    replica.merge_reduced = real
    repl.drain("r")
    assert repl.log.pending_count("r") == 0
    assert_dumps_identical(home, replica, spec, "post partial-frame recovery")


def test_bootstrap_chunks_retry_then_fail_loudly():
    """Bootstrap chunks are not log entries — a silently lost one would be
    lost forever — so the stream retries per chunk and raises
    DeliveryError when the channel never carries it."""
    from repro.core.channel import DeliveryError

    t = topo()
    channel = ScriptedChannel(t)
    g = geo_store(
        topology=t,
        channel=channel,
        delivery_policy=DeliveryPolicy(bootstrap_retries=3),
    )
    drive(g, ticks=2)
    channel.down = True
    with pytest.raises(DeliveryError, match="bootstrap chunk"):
        g.add_replica("near")
    st_count = g.fs.monitor.system.counters
    assert st_count.get("replication/timeout/near", 0) >= 4  # 1 try + 3 retries


# -- fault plan purity ---------------------------------------------------------


def test_fault_plan_is_pure_seeded_and_honors_partitions():
    plan = FaultPlan(seed=7, drop_rate=0.3, dup_rate=0.2)
    a = [plan.decide("r", e) for e in range(200)]
    assert a == [plan.decide("r", e) for e in range(200)]  # pure
    other_seed = FaultPlan(seed=8, drop_rate=0.3, dup_rate=0.2)
    assert [other_seed.decide("r", e) for e in range(200)] != a
    drops = sum("drop" in f for f in a)
    assert 30 <= drops <= 90  # ~0.3 of 200, loosely
    # corruption must always actually change the bytes (CRC must fire)
    data = bytes(range(64))
    for e in range(32):
        assert plan.corrupt("r", e, data) != data
    p = FaultPlan(seed=1, drop_rate=1.0, partitions=(("r", 0, 5),))
    assert p.decide("r", 0) == ["partition"]
    assert p.partitioned("r", 4) and not p.partitioned("r", 5)
    assert not p.partitioned("other", 2)


def test_faulty_channel_counts_what_it_injects():
    t = topo()
    channel = FaultyChannel(FaultPlan(seed=3, drop_rate=0.5), t)
    probe = wire.encode_probe()
    deliveries = [channel.transmit("home", "near", probe) for _ in range(60)]
    assert channel.counts["transmits"] == 60
    dropped = sum(1 for d in deliveries if not d.arrivals)
    assert channel.counts["dropped"] == dropped > 0
    # a different destination draws an independent schedule
    channel.transmit("home", "far", probe)
    assert channel.events == {"near": 60, "far": 1}


def test_in_process_channel_is_perfect():
    t = topo()
    channel = InProcessChannel(t)
    frame = wire.encode_probe()
    d = channel.transmit("home", "near", frame)
    assert d.arrivals == (frame.data,)
    assert d.ack_lost is False
    assert d.latency_ms == t.transfer_ms("home", "near", frame.wire_nbytes)


def test_route_read_raises_when_detection_downs_the_only_replica():
    """Detection composes with the standing routing contract: when every
    serving candidate is detected-down, route_read raises RegionDownError
    (home is always a candidate, so kill the home read path by lag)."""
    t = topo()
    channel = ScriptedChannel(t)
    g = geo_store(
        topology=t,
        channel=channel,
        delivery_policy=FAST_POLICY,
        replica_regions=("near",),
    )
    g.tick(HOUR)
    channel.down = True
    for _ in range(12):
        g.drain()
    assert t.regions["near"].healthy is False
    # the home still serves; the detected-down replica is never picked
    serving, _ = g.route_read("near")
    assert serving == "home"
    g.mark_down("home")
    with pytest.raises(RegionDownError):
        g.route_read("near")
