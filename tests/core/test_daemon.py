"""Replica-daemon e2e over a real localhost socket (ISSUE 8 tentpole).

Every test here spawns ``repro.core.daemon`` as a genuine child process
(``python -m``, own interpreter, own stores) and talks to it through the
stream envelope — no in-process shortcuts.  The claims:

  * ROUND TRIP — frames transmitted through ``SocketChannel`` are applied
    by the child and acked with exactly the seqs shipped; the daemon's
    ledger accounts for every message;
  * IDEMPOTENCE — redelivering an already-applied frame over the socket
    is acked again (same seqs) and leaves the daemon's state bit-identical
    (at-least-once delivery, exactly-once effect — now across a process
    boundary);
  * CONVERGENCE — a ``GeoReplicator`` with a remote replica drains both
    planes to pending==0, and ``promote`` adopts the daemon's state into
    an in-process store byte-identically online / chunk-set-identically
    offline;
  * PIPELINING — the windowed in-flight drain produces the same replica
    state as the serialized (window=1) drain on the same workload;
  * FAULTS — the ``SocketChannel`` fault-proxy mode (seeded ``FaultPlan``)
    injects corruption and drops on the REAL wire; the delivery state
    machine retries through them and still converges.

Marked ``proc``: each test pays ~1 s of child-interpreter startup, and CI
runs this module in the parallel process-test lane.
"""

import os

import numpy as np
import pytest

from repro.core import wire
from repro.core.assets import (
    Entity,
    Feature,
    FeatureSetSpec,
    MaterializationSettings,
)
from repro.core.channel import FaultPlan
from repro.core.daemon import SocketChannel, spawn_replica_daemon
from repro.core.dsl import UDFTransform
from repro.core.offline_store import OfflineStore
from repro.core.online_store import OnlineStore
from repro.core.regions import GeoTopology, Region
from repro.core.replication import (
    DeliveryPolicy,
    GeoReplicator,
    ReplicationLog,
)
from repro.core.table import Table

pytestmark = pytest.mark.proc

HOUR = 3_600_000


def _spec(name="geo", online=True, offline=True):
    return FeatureSetSpec(
        name=name,
        version=1,
        entity=Entity("cust", ("entity_id",)),
        features=(Feature("f0"), Feature("f1")),
        source_name="src",
        transform=UDFTransform(lambda df, ctx: df, name="id"),
        materialization=MaterializationSettings(online, offline),
    )


def _frame(rng, n, entities, t0):
    return Table(
        {
            "entity_id": rng.integers(0, entities, n).astype(np.int64),
            "ts": (t0 + rng.integers(0, HOUR, n)).astype(np.int64),
            "f0": rng.random(n).astype(np.float32),
            "f1": rng.random(n).astype(np.float32),
        }
    )


def _topo():
    return GeoTopology(regions={r: Region(r) for r in ("westus2", "eastus")})


def _replicator(policy=None, offline=True):
    home = OnlineStore()
    home_off = OfflineStore() if offline else None
    rep = GeoReplicator(
        home,
        topology=_topo(),
        home_region="westus2",
        home_offline=home_off,
        log=ReplicationLog(capacity=1024),
        policy=policy or DeliveryPolicy(),
    )
    return rep, home, home_off


def _publish(home, home_off, spec, rng, n_merges, rows=400):
    for i in range(n_merges):
        f = _frame(rng, rows, 1000, (i + 1) * HOUR)
        home.merge(spec, f, 10**8 + i)
        if home_off is not None:
            home_off.merge(spec, f, 10**8 + i)


def _adopt_online(ch, spec):
    """Rebuild the daemon's online state locally from its dump stream."""
    store = OnlineStore()
    store.register(spec)
    for b in ch.fetch_dump(spec, "online"):
        store.merge_reduced(spec, b.keys, b.event_ts, b.values, b.creation_ts)
    return store


def _assert_online_identical(a: OnlineStore, b: OnlineStore, spec):
    da = a.dump_all(spec.name, spec.version)
    db = b.dump_all(spec.name, spec.version)
    assert da.names == db.names
    for name in da.names:
        np.testing.assert_array_equal(da[name], db[name], err_msg=name)


def _assert_offline_identical(a: OfflineStore, b: OfflineStore, spec):
    ha = a.canonical_history(spec.name, spec.version)
    hb = b.canonical_history(spec.name, spec.version)
    assert len(ha) == len(hb)
    for name in ha.names:
        np.testing.assert_array_equal(ha[name], hb[name], err_msg=name)


# -- round trip ---------------------------------------------------------------


def test_round_trip_acks_and_ledger():
    rep, home, home_off = _replicator()
    spec = _spec()
    rng = np.random.default_rng(0)
    with spawn_replica_daemon(region="eastus") as h:
        ch = SocketChannel(h.connect(), src="westus2", dst="eastus")
        rep.add_remote_replica("eastus", ch, offline=True)
        _publish(home, home_off, spec, rng, 4)
        out = rep.drain("eastus")
        assert out["eastus"]["applied_batches"] == 8  # 4 online + 4 offline
        assert rep.lag_batches("eastus") == 0
        st = rep.delivery["eastus"]
        assert st.status == "healthy"
        assert st.timeouts == 0 and st.corrupt_frames == 0
        ledger = ch.ledger()
        assert ledger["batches_applied"] == 8
        assert ledger["rows_applied"] > 0
        assert ledger["nacks"] == 0
        ch.close()


def test_redelivery_over_socket_is_idempotent():
    """Re-transmit every already-acked batch over the same pipe: the
    daemon acks each again and its state stays bit-identical to home."""
    rep, home, _ = _replicator(offline=False)
    spec = _spec(offline=False)
    rng = np.random.default_rng(1)
    with spawn_replica_daemon(region="eastus", offline=False) as h:
        ch = SocketChannel(h.connect(), src="westus2", dst="eastus")
        rep.add_remote_replica("eastus", ch)
        _publish(home, None, spec, rng, 3)
        # capture the pending batches BEFORE draining (the log truncates
        # its fully-acked prefix afterwards)
        redelivered = list(rep.log.pending("eastus"))
        assert redelivered
        rep.drain("eastus")
        assert rep.lag_batches("eastus") == 0
        before = ch.ledger()
        for b in redelivered:
            delivery = ch.transmit("westus2", "eastus", wire.encode_batch(b))
            ack = delivery.remote
            assert ack is not None and ack.ok
            assert ack.seqs == (b.seq,)
        after = ch.ledger()
        assert after["frames"] == before["frames"] + len(redelivered)
        _assert_online_identical(home, _adopt_online(ch, spec), spec)
        ch.close()


# -- convergence + promote ----------------------------------------------------


def test_replicator_converges_and_promote_adopts_both_planes():
    rep, home, home_off = _replicator(policy=DeliveryPolicy(inflight_window=8))
    spec = _spec()
    rng = np.random.default_rng(2)
    with spawn_replica_daemon(region="eastus") as h:
        ch = SocketChannel(
            h.connect(), src="westus2", dst="eastus", topology=rep.topology
        )
        rep.add_remote_replica("eastus", ch, offline=True)
        _publish(home, home_off, spec, rng, 6)
        rep.drain("eastus")
        assert rep.lag_batches("eastus") == 0
        # un-drained tail: promote must force-drain it before adopting
        _publish(home, home_off, spec, rng, 2)
        home_dump = home.dump_all(spec.name, spec.version)
        rep.promote("eastus")
        assert rep.home_region == "eastus"
        assert "eastus" not in rep.remote  # adopted into the store map
        db = rep.stores["eastus"].dump_all(spec.name, spec.version)
        for name in home_dump.names:
            np.testing.assert_array_equal(home_dump[name], db[name], err_msg=name)
        _assert_offline_identical(home_off, rep.offline_stores["eastus"], spec)
        # the link actually measured: the RTT gauge saw real acks
        assert rep.topology.measured_latency("westus2", "eastus") is not None
        ch.close()


def test_pipelined_drain_matches_serialized():
    """Same two-table workload into two daemons — one drained window=1,
    one window=8 (alternating tables keep the coalesced runs short, so
    the window genuinely holds multiple frames in flight) — must land
    byte-identical online state."""
    stores = []
    spec_a = _spec("geo_a", offline=False)
    spec_b = _spec("geo_b", offline=False)
    for window in (1, 8):
        rep, home, _ = _replicator(
            policy=DeliveryPolicy(inflight_window=window), offline=False
        )
        rng = np.random.default_rng(3)
        with spawn_replica_daemon(region="eastus", offline=False) as h:
            ch = SocketChannel(h.connect(), src="westus2", dst="eastus")
            rep.add_remote_replica("eastus", ch)
            for i in range(6):
                home.merge(spec_a, _frame(rng, 200, 500, (i + 1) * HOUR), 10**8 + i)
                home.merge(spec_b, _frame(rng, 200, 500, (i + 1) * HOUR), 10**8 + i)
            rep.drain("eastus")
            assert rep.lag_batches("eastus") == 0
            stores.append(
                (_adopt_online(ch, spec_a), _adopt_online(ch, spec_b))
            )
            ch.close()
    _assert_online_identical(stores[0][0], stores[1][0], spec_a)
    _assert_online_identical(stores[0][1], stores[1][1], spec_b)


# -- faults on the real wire --------------------------------------------------


def test_fault_proxy_corrupt_and_drop_still_converges():
    """Seeded drops + corruption on the actual socket: the daemon NACKs
    corrupt frames (intact envelope, damaged payload), drops surface as
    publisher timeouts, and repeated draining converges anyway."""
    policy = DeliveryPolicy(
        suspect_after=2,
        dead_after=6,
        backoff_base=1,
        backoff_cap=2,
        probe_interval=1,
        inflight_window=1,  # serialized so per-transmit faults are exact
    )
    rep, home, _ = _replicator(policy=policy, offline=False)
    spec_a = _spec("geo_a", offline=False)
    spec_b = _spec("geo_b", offline=False)
    rng = np.random.default_rng(4)
    plan = FaultPlan(seed=99, drop_rate=0.25, corrupt_rate=0.25)
    with spawn_replica_daemon(region="eastus", offline=False) as h:
        ch = SocketChannel(
            h.connect(), src="westus2", dst="eastus", fault_plan=plan
        )
        rep.add_remote_replica("eastus", ch)
        # alternating tables keep the coalesced runs short: many transmit
        # events, so the per-event fault draws actually strike
        for i in range(6):
            home.merge(spec_a, _frame(rng, 300, 1000, (i + 1) * HOUR), 10**8 + i)
            home.merge(spec_b, _frame(rng, 300, 1000, (i + 1) * HOUR), 10**8 + i)
        for _ in range(40):
            if rep.lag_batches("eastus") == 0:
                break
            rep.drain("eastus")
        assert rep.lag_batches("eastus") == 0
        assert ch.counts["dropped"] + ch.counts["corrupted"] > 0
        st = rep.delivery["eastus"]
        assert st.timeouts > 0  # the faults were really felt
        ledger = ch.ledger()
        assert ledger["nacks"] == ch.counts["corrupted"]
        _assert_online_identical(home, _adopt_online(ch, spec_a), spec_a)
        _assert_online_identical(home, _adopt_online(ch, spec_b), spec_b)
        ch.close()


def test_daemon_teardown_leaves_no_orphan():
    """DaemonHandle.close terminates the child; nothing survives it."""
    h = spawn_replica_daemon(region="eastus")
    pid = h.proc.pid
    h.close()
    assert h.proc.poll() is not None
    with pytest.raises(ProcessLookupError):
        os.kill(pid, 0)
