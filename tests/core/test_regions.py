"""§4.1.2 geo-distribution control plane: routing, replication, compliance,
fail-over."""

import pytest

from repro.core.regions import (
    ComplianceError,
    GeoPlacement,
    GeoTopology,
    Region,
    RegionDownError,
    ReplicationPolicy,
)


def _topo(fenced_home=False):
    return GeoTopology(
        regions={
            "home": Region("home", geo_fenced=fenced_home),
            "remote1": Region("remote1"),
            "remote2": Region("remote2"),
        },
        local_latency_ms=1.0,
        cross_region_latency_ms=60.0,
    )


def test_cross_region_access_serves_from_home():
    geo = GeoPlacement(_topo(), "home", ReplicationPolicy.CROSS_REGION_ACCESS)
    assert geo.route_read("home") == ("home", 1.0)
    assert geo.route_read("remote1") == ("home", 60.0)
    # replication requires the GEO_REPLICATED policy
    with pytest.raises(ComplianceError):
        geo.add_replica("remote1")


def test_replication_makes_reads_local():
    geo = GeoPlacement(_topo(), "home", ReplicationPolicy.GEO_REPLICATED)
    geo.add_replica("remote1")
    assert geo.route_read("remote1") == ("remote1", 1.0)
    assert geo.route_read("remote2") == ("home", 60.0) or geo.route_read(
        "remote2"
    )[1] == 60.0


def test_geo_fencing():
    geo = GeoPlacement(_topo(fenced_home=True), "home",
                       ReplicationPolicy.GEO_REPLICATED)
    with pytest.raises(ComplianceError):
        geo.add_replica("remote1")


def test_failover_promotes_and_restores():
    geo = GeoPlacement(_topo(), "home", ReplicationPolicy.GEO_REPLICATED)
    geo.add_replica("remote1")
    geo.mark_down("home")
    assert geo.failover() == "remote1"
    assert geo.route_read("home")[0] == "remote1"
    geo.mark_up("home")
    assert geo.failover() is None  # healthy home: nothing to do


def test_failover_prefers_nearest_healthy_replica():
    """The docstring's promise, kept: promotion follows the topology's
    latency model (with per-link overrides), not replica-set order."""
    topo = GeoTopology(
        regions={r: Region(r) for r in ("home", "near", "far")},
        local_latency_ms=1.0,
        cross_region_latency_ms=60.0,
        link_latency_ms={("home", "near"): 20.0, ("home", "far"): 90.0},
    )
    geo = GeoPlacement(topo, "home", ReplicationPolicy.GEO_REPLICATED)
    geo.add_replica("far")
    geo.add_replica("near")
    geo.mark_down("home")
    assert geo.failover() == "near"
    # symmetric link lookup: (near, far) falls back to the WAN default
    assert topo.latency("far", "home") == 90.0
    assert topo.latency("near", "far") == 60.0


def test_topology_transfer_cost_model():
    topo = GeoTopology(
        regions={r: Region(r) for r in ("a", "b")},
        cross_region_latency_ms=50.0,
        cross_region_gbps=1.0,
    )
    assert topo.transfer_ms("a", "a", 10**9) == 0.0  # local ships are free
    # 1 MB over a 1 Gbps WAN link: 50 ms latency + 8 ms serialization
    assert topo.transfer_ms("a", "b", 10**6) == pytest.approx(58.0)


def test_no_healthy_replica_raises():
    geo = GeoPlacement(_topo(), "home", ReplicationPolicy.CROSS_REGION_ACCESS)
    geo.mark_down("home")
    with pytest.raises(RegionDownError):
        geo.route_read("remote1")
    with pytest.raises(RegionDownError):
        geo.failover()


def test_read_log_records_routing():
    geo = GeoPlacement(_topo(), "home", ReplicationPolicy.CROSS_REGION_ACCESS)
    geo.route_read("remote1")
    geo.route_read("home")
    assert geo.read_log == [("remote1", "home", 60.0), ("home", "home", 1.0)]
