"""Test-suite bootstrap.

This environment may not ship ``hypothesis``.  Rather than skipping the 12
property-style test modules wholesale, install a minimal deterministic
fallback implementing exactly the surface the suite uses: ``given``,
``settings``, and ``strategies`` {integers, floats, booleans, sampled_from,
tuples, lists}.  Examples are drawn from a per-test seeded RNG (reproducible
runs) with boundary values front-loaded, so the tests keep their
property-checking character even without the real shrinker.
"""

from __future__ import annotations

import inspect
import random
import sys
import types
import zlib


def _install_hypothesis_fallback() -> None:
    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng: random.Random):
            return self._draw(rng)

    def integers(min_value=None, max_value=None):
        lo = -(2**63) if min_value is None else int(min_value)
        hi = 2**63 - 1 if max_value is None else int(max_value)
        boundary = [lo, hi, min(lo + 1, hi), max(hi - 1, lo), min(max(0, lo), hi)]

        def draw(rng):
            if rng.random() < 0.2:
                return rng.choice(boundary)
            return rng.randint(lo, hi)

        return _Strategy(draw)

    def floats(min_value=None, max_value=None, **_kw):
        lo = -1e9 if min_value is None else float(min_value)
        hi = 1e9 if max_value is None else float(max_value)

        def draw(rng):
            if rng.random() < 0.15:
                return rng.choice([lo, hi])
            return rng.uniform(lo, hi)

        return _Strategy(draw)

    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))

    def tuples(*strategies):
        return _Strategy(lambda rng: tuple(s.draw(rng) for s in strategies))

    def lists(elements, min_size=0, max_size=None, **_kw):
        hi = max_size if max_size is not None else min_size + 10

        def draw(rng):
            n = rng.randint(min_size, hi)
            return [elements.draw(rng) for _ in range(n)]

        return _Strategy(draw)

    class settings:
        def __init__(self, max_examples=100, deadline=None, **_kw):
            self.max_examples = max_examples

        def __call__(self, fn):
            fn._fallback_settings = self
            return fn

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                cfg = getattr(wrapper, "_fallback_settings", None) or getattr(
                    fn, "_fallback_settings", None
                )
                n = cfg.max_examples if cfg else 25
                seed = zlib.crc32(
                    f"{fn.__module__}.{fn.__qualname__}".encode()
                )
                rng = random.Random(seed)
                for _ in range(n):
                    drawn = [s.draw(rng) for s in arg_strategies]
                    kd = {k: s.draw(rng) for k, s in kw_strategies.items()}
                    fn(*args, *drawn, **kwargs, **kd)

            # Hide the strategy-supplied parameters from pytest's fixture
            # resolution (positional strategies fill the RIGHTMOST params).
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            if arg_strategies:
                params = params[: -len(arg_strategies)]
            params = [p for p in params if p.name not in kw_strategies]
            wrapper.__signature__ = sig.replace(parameters=params)
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__module__ = fn.__module__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco

    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.floats = floats
    st.booleans = booleans
    st.sampled_from = sampled_from
    st.tuples = tuples
    st.lists = lists

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.strategies = st
    mod.__fallback__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


try:  # pragma: no cover - environment probe
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover
    _install_hypothesis_fallback()
