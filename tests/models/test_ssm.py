"""Mamba2 SSD: chunked (matmul, train) form vs naive recurrence oracle, and
decode-step agreement with the full-sequence forward."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import ssm
from repro.models.config import ModelConfig


def _cfg(l_chunk=16):
    return ModelConfig(
        name="m", family="ssm", num_layers=1, d_model=32, vocab_size=64,
        ssm=True, ssm_state=8, ssm_expand=2, ssm_head_dim=8, ssm_groups=1,
        ssm_conv_width=4, ssm_chunk=l_chunk,
        param_dtype="float32", compute_dtype="float32",
    )


def _ssd_inputs(b, l, h, p, g, n, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    xs = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)) - 1.0)
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bs = jax.random.normal(ks[3], (b, l, g, n))
    cs = jax.random.normal(ks[0], (b, l, g, n))
    return xs, dt, a, bs, cs


@pytest.mark.parametrize("l,chunk", [(32, 8), (64, 16), (128, 128), (48, 16)])
def test_ssd_chunked_matches_recurrence(l, chunk):
    cfg = _cfg(chunk)
    xs, dt, a, bs, cs = _ssd_inputs(2, l, 4, 8, 1, 8)
    y_c, s_c = ssm._ssd_chunked(xs, dt, a, bs, cs, cfg)
    y_r, s_r = ssm.ssd_reference(xs, dt, a, bs, cs)
    np.testing.assert_allclose(y_c, y_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s_c, s_r, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), groups=st.sampled_from([1, 2, 4]))
def test_ssd_property_grouped_heads(seed, groups):
    """GQA-style B/C groups (H % G == 0) must match the oracle too."""
    cfg = _cfg(8)
    xs, dt, a, bs, cs = _ssd_inputs(1, 32, 4, 8, groups, 8, seed)
    y_c, _ = ssm._ssd_chunked(xs, dt, a, bs, cs, cfg)
    y_r, _ = ssm.ssd_reference(xs, dt, a, bs, cs)
    np.testing.assert_allclose(y_c, y_r, rtol=2e-4, atol=2e-4)


def test_block_decode_matches_forward():
    """Stepping mamba_decode over a sequence must equal mamba_forward
    (the long_500k serving plan relies on this recurrent path)."""
    cfg = _cfg(16)
    params = ssm.mamba_init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32)) * 0.3

    y_full = ssm.mamba_forward(params, x, cfg)

    state = ssm.init_mamba_state(cfg, 2, dtype=jnp.float32)
    outs = []
    for t in range(32):
        y_t, state = ssm.mamba_decode(params, x[:, t : t + 1], state, cfg)
        outs.append(y_t)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(y_step, y_full, rtol=2e-3, atol=2e-3)


def test_state_is_constant_memory():
    """The decode state must not grow with sequence length — the whole point
    of the SSM family owning the long_500k cells."""
    cfg = _cfg()
    s = ssm.init_mamba_state(cfg, 1, dtype=jnp.float32)
    n_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(s))
    assert n_bytes < 200_000  # KBs, independent of context length
