"""MoE dispatch equivalence: sort-based (production) vs GShard einsum
(oracle), single-device GSPMD path vs shard_map EP path (subprocess with 8
fake devices), drop policies, gradients."""

import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import moe
from repro.models.config import ModelConfig


def _cfg(e=8, k=2, shared=1):
    return ModelConfig(
        name="t", family="moe", num_layers=2, d_model=32, vocab_size=64,
        num_heads=2, num_kv_heads=2, head_dim=16, moe=True, num_experts=e,
        top_k=k, moe_d_ff=16, num_shared_experts=shared, d_ff=16,
        param_dtype="float32", compute_dtype="float32",
    )


def _params(cfg, seed=0):
    return moe.moe_init(jax.random.PRNGKey(seed), cfg, dtype=jnp.float32)


@pytest.mark.parametrize("e,k", [(4, 1), (8, 2), (16, 4)])
def test_sort_matches_einsum_no_drop(e, k):
    cfg = _cfg(e, k)
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32))
    y1, a1 = moe.moe_apply(p, x, cfg, group_size=32, capacity_factor=float(e))
    y2, a2 = moe.moe_apply_einsum(p, x, cfg, group_size=32, capacity_factor=float(e))
    np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(a1, a2, rtol=1e-6)


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), cf=st.floats(1.0, 2.0))
def test_sort_matches_einsum_drop_policy(seed, cf):
    """When capacity binds, both paths must drop the SAME assignments
    (GShard priority: earlier tokens, then lower expert-choice rank)."""
    cfg = _cfg(8, 2)
    p = _params(cfg, seed % 7)
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 64, 32))
    y1, _ = moe.moe_apply(p, x, cfg, group_size=64, capacity_factor=cf)
    y2, _ = moe.moe_apply_einsum(p, x, cfg, group_size=64, capacity_factor=cf)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-4)


@pytest.mark.slow
def test_gradients_match_oracle():
    cfg = _cfg()
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 32))

    def loss_sort(p):
        return moe.moe_apply(p, x, cfg, group_size=16, capacity_factor=8.0)[0].sum()

    def loss_ein(p):
        return moe.moe_apply_einsum(p, x, cfg, group_size=16, capacity_factor=8.0)[0].sum()

    g1, g2 = jax.grad(loss_sort)(p), jax.grad(loss_ein)(p)
    worst = max(
        jax.tree.leaves(jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), g1, g2))
    )
    assert worst < 1e-4


def test_aux_loss_balanced_vs_skewed():
    """The switch aux loss must penalize a skewed router more than a uniform
    one (sanity of the load-balance objective)."""
    cfg = _cfg(8, 2, shared=0)
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 128, 32))
    _, aux_learned = moe.moe_apply(p, x, cfg, group_size=128)
    # force skew: router always picks expert 0 by biasing its column
    p_skew = dict(p)
    p_skew["router"] = p["router"].at[:, 0].add(100.0)
    _, aux_skew = moe.moe_apply(p_skew, x, cfg, group_size=128)
    assert float(aux_skew) > float(aux_learned)


_EP_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from repro.models import moe
    from repro.models.config import ModelConfig
    from repro.models.pspec import activation_mesh

    cfg = ModelConfig(
        name="t", family="moe", num_layers=2, d_model=32, vocab_size=64,
        num_heads=2, num_kv_heads=2, head_dim=16, moe=True, num_experts=8,
        top_k=2, moe_d_ff=16, num_shared_experts=1, d_ff=16,
        param_dtype="float32", compute_dtype="float32",
    )
    p = moe.moe_init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 128, 32))

    y_ref, a_ref = moe.moe_apply_einsum(p, x, cfg, group_size=64,
                                        capacity_factor=8.0)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with mesh, activation_mesh(mesh):
        y_ep, a_ep = jax.jit(
            lambda p, x: moe.moe_apply(p, x, cfg, group_size=64,
                                       capacity_factor=8.0)
        )(p, x)
        # gradient through the EP block
        g = jax.jit(jax.grad(lambda p: moe.moe_apply(
            p, x, cfg, group_size=64, capacity_factor=8.0)[0].sum()))(p)
    g_ref = jax.grad(lambda p: moe.moe_apply_einsum(
        p, x, cfg, group_size=64, capacity_factor=8.0)[0].sum())(p)
    gd = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), g, g_ref)))
    print("EP_RESULT " + json.dumps({
        "y_diff": float(jnp.abs(y_ep - y_ref).max()),
        "aux_diff": float(abs(a_ep - a_ref)),
        "grad_diff": gd,
    }))
    """
)


@pytest.mark.proc
def test_ep_shard_map_matches_oracle_subprocess():
    proc = subprocess.run(
        [sys.executable, "-c", _EP_SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("EP_RESULT")]
    res = json.loads(line[0].split(" ", 1)[1])
    assert res["y_diff"] < 1e-4, res
    assert res["aux_diff"] < 1e-4, res
    assert res["grad_diff"] < 5e-3, res
