"""Per-architecture smoke tests (assignment requirement).

For EVERY assigned arch: instantiate the REDUCED config of the same family
and run one forward + train steps + decode steps on CPU, asserting output
shapes and finite values.  Full configs are exercised only by the dry-run
(abstract, no allocation).

Compile cost dominates on the 1-core CPU container, so each arch's params
and jitted steps are built once (module cache) and shared across its tests.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.configs.shapes import ALL_ARCHS
from repro.launch.steps import TrainState, make_serve_step, make_train_step
from repro.models import api
from repro.optim.adamw import adamw

# compile-heavy across every assigned arch — the whole module rides the
# parallel slow lane in CI (scripts/tier1.sh runs it locally as always)
pytestmark = pytest.mark.slow

BATCH, SEQ = 2, 32
_CACHE: dict = {}


def _finite(tree) -> bool:
    return all(
        bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(tree)
        if jnp.issubdtype(x.dtype, jnp.floating)
    )


def _ctx(arch):
    if arch not in _CACHE:
        cfg = get_config(arch, reduced=True)
        # float32 on CPU: bf16 emulation is slow and loose
        cfg = dataclasses.replace(cfg, param_dtype="float32", compute_dtype="float32")
        params = api.init_params(jax.random.PRNGKey(0), cfg, max_decode_len=64)
        _CACHE[arch] = {"cfg": cfg, "params": params}
    return _CACHE[arch]


def test_registry_covers_assignment():
    assert set(list_archs()) == set(ALL_ARCHS)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    c = _ctx(arch)
    cfg, params = c["cfg"], c["params"]
    batch = api.make_dummy_batch(cfg, BATCH, SEQ)
    logits = jax.jit(lambda p, b: api.forward_logits(p, b, cfg))(params, batch)
    n_prefix = cfg.num_patches if cfg.vision_prefix else 0
    assert logits.shape == (BATCH, SEQ + n_prefix, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_loss_decreases(arch):
    c = _ctx(arch)
    cfg, params = c["cfg"], c["params"]
    opt = adamw(lr=1e-3, weight_decay=0.0)
    state = TrainState.create(params, opt)
    step = jax.jit(make_train_step(cfg, opt))
    batch = api.make_dummy_batch(cfg, BATCH, SEQ)
    state, metrics = step(state, batch)
    assert int(state.step) == 1
    assert _finite(state.params), f"{arch}: non-finite params after update"
    l0 = float(metrics["total_loss"])
    assert np.isfinite(l0)
    for _ in range(3):
        state, metrics = step(state, batch)
    assert float(metrics["total_loss"]) < l0, f"{arch}: loss not decreasing"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_steps_match_prefill(arch):
    """Greedy decode through the cache must equal argmax of the full forward
    at the same positions (decode-path/train-path consistency)."""
    c = _ctx(arch)
    cfg, params = c["cfg"], c["params"]
    serve = jax.jit(make_serve_step(cfg))
    toks = api.make_dummy_batch(cfg, BATCH, 8)["tokens"]
    batch = {"tokens": toks}
    if cfg.encoder_decoder:
        batch["frames"] = api.make_dummy_batch(cfg, BATCH, 8)["frames"]

    cache = api.init_cache(cfg, BATCH, 64)
    if cfg.encoder_decoder:
        memory = api.encode_memory(params, batch["frames"], cfg)
        cache = api.attach_memory(cache, memory, params, cfg)
    outs = []
    for t in range(8):
        nxt, cache = serve(params, cache, toks[:, t : t + 1])
        outs.append(nxt)
    got = np.stack([np.asarray(o).reshape(BATCH) for o in outs], axis=1)

    # decode runs no-drop MoE; compare against a no-drop forward
    fwd_cfg = (
        dataclasses.replace(cfg, capacity_factor=cfg.num_experts / cfg.top_k)
        if cfg.moe else cfg
    )
    logits = jax.jit(lambda p, b: api.forward_logits(p, b, fwd_cfg))(params, batch)
    n_prefix = cfg.num_patches if cfg.vision_prefix else 0
    if cfg.vision_prefix:
        # decode path carries no vision prefix; contexts differ by design
        assert np.isfinite(np.asarray(logits)).all()
        return
    want = np.asarray(jnp.argmax(logits[:, n_prefix:], axis=-1))
    mismatch = (got != want).mean()
    assert mismatch == 0.0, f"{arch}: decode/prefill argmax mismatch {mismatch:.2%}"


@pytest.mark.parametrize("arch", ["phi3-medium-14b", "deepseek-v2-lite-16b", "mamba2-2.7b"])
def test_microbatched_train_matches_full(arch):
    """Gradient accumulation (µ=2) must match the single-batch step within
    float tolerance — the memory lever cannot change the math.  One arch per
    family (dense / MoE+MLA / SSM)."""
    c = _ctx(arch)
    cfg, params = c["cfg"], c["params"]
    opt = adamw(lr=1e-3, weight_decay=0.0)
    batch = api.make_dummy_batch(cfg, 4, 16)
    s1, _ = jax.jit(make_train_step(cfg, opt))(TrainState.create(params, opt), batch)
    s2, _ = jax.jit(make_train_step(cfg, opt, num_microbatches=2))(
        TrainState.create(params, opt), batch
    )
    diffs = jax.tree.map(
        lambda a, b: float(
            jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))
        ),
        s1.params, s2.params,
    )
    worst = max(jax.tree.leaves(diffs))
    assert worst < 5e-2, f"{arch}: µ-batched step diverges from full step ({worst})"
