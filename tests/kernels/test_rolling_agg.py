"""rolling_agg kernel vs pure-jnp oracle: shape/dtype sweeps + properties.

All Pallas execution is interpret=True (CPU container; TPU is the target).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.rolling_agg import ref as R
from repro.kernels.rolling_agg.ops import rolling_agg, rolling_sum, window_starts


def _random_case(rng, n, feat, n_seg, window, dtype=np.float32):
    seg = np.sort(rng.integers(0, n_seg, size=n))
    ts_jitter = np.sort(rng.integers(0, 50, size=n))
    # per-segment sorted timestamps
    ts = np.empty(n, np.int64)
    for s in np.unique(seg):
        m = seg == s
        ts[m] = np.sort(rng.integers(0, 1000, size=m.sum()))
    vals = rng.standard_normal((n, feat)).astype(dtype)
    starts = window_starts(seg, ts, window)
    return vals, starts, seg, ts


# ---------------------------------------------------------------------------
# window_starts (host-side span computation)
# ---------------------------------------------------------------------------
def test_window_starts_matches_bruteforce():
    rng = np.random.default_rng(0)
    for _ in range(10):
        n = int(rng.integers(1, 200))
        _, starts, seg, ts = _random_case(rng, n, 1, 5, int(rng.integers(1, 100)))
        window = None
    # recompute explicitly with a fixed window
    n = 150
    window = 30
    vals, starts, seg, ts = _random_case(np.random.default_rng(1), n, 1, 4, window)
    for i in range(n):
        in_win = [
            j
            for j in range(i + 1)
            if seg[j] == seg[i] and ts[i] - window < ts[j] <= ts[i]
        ]
        assert starts[i] == min(in_win), (i, starts[i], min(in_win))


def test_window_starts_rejects_unsorted():
    with pytest.raises(ValueError):
        window_starts(np.array([1, 0]), np.array([0, 0]), 10)


# ---------------------------------------------------------------------------
# kernel vs oracle: sweeps
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [1, 7, 255, 256, 257, 1024])
@pytest.mark.parametrize("feat", [1, 3, 128, 130])
def test_rolling_sum_shapes(n, feat):
    rng = np.random.default_rng(n * 1000 + feat)
    vals, starts, _, _ = _random_case(rng, n, feat, 3, 40)
    got = rolling_sum(jnp.asarray(vals), jnp.asarray(starts), hist=256)
    want = R.rolling_sum_ref(jnp.asarray(vals), jnp.asarray(starts))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [np.float32, np.float16, np.int32])
def test_rolling_sum_dtypes(dtype):
    rng = np.random.default_rng(42)
    n, feat = 300, 5
    vals, starts, _, _ = _random_case(rng, n, feat, 4, 25)
    if np.issubdtype(dtype, np.integer):
        vals = (vals * 10).astype(dtype)
    else:
        vals = vals.astype(dtype)
    got = rolling_sum(jnp.asarray(vals), jnp.asarray(starts), hist=128)
    want = R.rolling_sum_ref(jnp.asarray(vals), jnp.asarray(starts))
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(got, want, rtol=1e-2, atol=1e-2)


@pytest.mark.parametrize("agg", ["sum", "mean", "count", "min", "max"])
def test_rolling_agg_all_aggs(agg):
    rng = np.random.default_rng(7)
    vals, starts, _, _ = _random_case(rng, 200, 4, 3, 60)
    got = rolling_agg(jnp.asarray(vals), starts, agg)
    want = R.rolling_agg_ref(jnp.asarray(vals), jnp.asarray(starts), agg)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("block_rows,hist", [(64, 64), (64, 256), (256, 64), (128, 512)])
def test_rolling_sum_block_hist_sweep(block_rows, hist):
    """Spans bounded by hist; every (block, hist) tiling must agree."""
    rng = np.random.default_rng(block_rows + hist)
    n = 500
    vals = rng.standard_normal((n, 130)).astype(np.float32)
    max_span = hist
    starts = np.maximum(0, np.arange(n) - rng.integers(0, max_span, size=n)).astype(
        np.int32
    )
    got = rolling_sum(
        jnp.asarray(vals), jnp.asarray(starts), block_rows=block_rows, hist=hist
    )
    want = R.rolling_sum_ref(jnp.asarray(vals), jnp.asarray(starts))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_rolling_agg_deep_span_falls_back():
    """Spans deeper than the VMEM history bucket use the XLA path but stay
    correct."""
    n = 600
    vals = np.ones((n, 2), np.float32)
    starts = np.zeros(n, np.int32)  # every window reaches row 0: span = n
    got = rolling_agg(jnp.asarray(vals), starts, "sum")
    want = (np.arange(n) + 1).astype(np.float32)
    np.testing.assert_allclose(got[:, 0], want, rtol=1e-6)


# ---------------------------------------------------------------------------
# properties
# ---------------------------------------------------------------------------
@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 300),
    feat=st.integers(1, 9),
    window=st.integers(1, 80),
    seed=st.integers(0, 2**31 - 1),
)
def test_rolling_sum_property(n, feat, window, seed):
    rng = np.random.default_rng(seed)
    vals, starts, _, _ = _random_case(rng, n, feat, 4, window)
    got = rolling_sum(jnp.asarray(vals), jnp.asarray(starts), hist=256)
    want = R.rolling_sum_ref(jnp.asarray(vals), jnp.asarray(starts))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_rolling_mean_bounded_by_extremes(seed):
    """mean(window) must lie within [min(window), max(window)]."""
    rng = np.random.default_rng(seed)
    vals, starts, _, _ = _random_case(rng, 128, 3, 3, 30)
    mean = np.asarray(rolling_agg(jnp.asarray(vals), starts, "mean"))
    lo = np.asarray(rolling_agg(jnp.asarray(vals), starts, "min"))
    hi = np.asarray(rolling_agg(jnp.asarray(vals), starts, "max"))
    assert (mean >= lo - 1e-4).all() and (mean <= hi + 1e-4).all()


def test_window_never_crosses_entity_boundary():
    """Rows of entity A must never contribute to entity B's windows."""
    seg = np.array([0] * 50 + [1] * 50)
    ts = np.concatenate([np.arange(50), np.arange(50)]).astype(np.int64)
    vals = np.where(seg[:, None] == 0, 1000.0, 1.0).astype(np.float32)
    starts = window_starts(seg, ts, window=100)
    out = np.asarray(rolling_agg(jnp.asarray(vals), starts, "sum"))
    # entity 1 rows: sums of ones only
    assert (out[50:, 0] <= 50.0).all()
    assert out[50, 0] == 1.0
