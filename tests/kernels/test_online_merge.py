"""online_merge kernel vs oracle: latest-wins update, routing, padding."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.online_lookup.ops import combine_i64, partition_of, split_i64
from repro.kernels.online_merge.ops import merge, route_and_merge, route_flat
from repro.kernels.online_merge.ref import merge_ref


def _build_table(rng, num_p, cap, n_live, dim=3):
    """Random live table in the shared partitioned layout."""
    ids = rng.choice(np.arange(1, 10_000_000), size=n_live, replace=False).astype(
        np.int64
    )
    keys = np.full((num_p, cap), -1, np.int64)
    ev = np.zeros((num_p, cap), np.int64)
    cr = np.zeros((num_p, cap), np.int64)
    vals = np.zeros((num_p, cap, dim), np.float32)
    part = partition_of(ids, num_p)
    fill = np.zeros(num_p, np.int64)
    kept = []
    for j in range(n_live):
        p = part[j]
        if fill[p] >= cap:
            continue
        keys[p, fill[p]] = ids[j]
        ev[p, fill[p]] = rng.integers(0, 1000)
        cr[p, fill[p]] = rng.integers(1000, 2000)
        vals[p, fill[p]] = float(ids[j] % 89)
        fill[p] += 1
        kept.append(ids[j])
    return keys, ev, cr, vals, np.array(kept, np.int64)


def _planes(keys):
    lo, hi = split_i64(keys)
    return lo, hi


def _run_kernel(keys, ev, cr, vals, q_ids, q_ev, q_vals, batch_cr):
    klo, khi = _planes(keys)
    elo, ehi = split_i64(ev)
    clo, chi = split_i64(cr)
    qlo, qhi = split_i64(q_ids)
    pad = q_ids == -2
    qlo[pad] = -2
    qhi[pad] = -2
    qelo, qehi = split_i64(q_ev)
    cr_planes = np.asarray(
        np.concatenate(split_i64(np.asarray([batch_cr]))), np.int32
    )
    out = merge(
        jnp.asarray(klo), jnp.asarray(khi),
        jnp.asarray(elo), jnp.asarray(ehi),
        jnp.asarray(clo), jnp.asarray(chi),
        jnp.asarray(vals),
        jnp.asarray(qlo), jnp.asarray(qhi),
        jnp.asarray(qelo), jnp.asarray(qehi),
        jnp.asarray(q_vals), jnp.asarray(cr_planes),
    )
    ev_u = combine_i64(np.asarray(out[0]), np.asarray(out[1]))
    cr_u = combine_i64(np.asarray(out[2]), np.asarray(out[3]))
    return ev_u, cr_u, np.asarray(out[4])


@pytest.mark.parametrize("num_p,cap,q", [(1, 64, 16), (4, 512, 100), (8, 100, 7)])
def test_merge_vs_ref(num_p, cap, q):
    rng = np.random.default_rng(num_p * cap + q)
    keys, ev, cr, vals, live = _build_table(rng, num_p, cap, num_p * cap // 2)
    # routed queries: mix of hits (latest and stale) and misses, unique ids
    # per partition row
    n_pick = min(q * num_p, len(live))
    picked = rng.choice(live, size=n_pick, replace=False)
    q_ids = np.full((num_p, q), -2, np.int64)
    q_ev = np.zeros((num_p, q), np.int64)
    q_vals = np.zeros((num_p, q, vals.shape[-1]), np.float32)
    part = partition_of(picked, num_p)
    pos = np.zeros(num_p, np.int64)
    for j, pid in enumerate(picked):
        p = part[j]
        if pos[p] >= q:
            continue
        q_ids[p, pos[p]] = pid
        q_ev[p, pos[p]] = rng.integers(0, 2000)  # half stale, half newer
        q_vals[p, pos[p]] = float(pid % 31)
        pos[p] += 1
    batch_cr = int(rng.integers(500, 2500))
    got = _run_kernel(keys, ev, cr, vals, q_ids, q_ev, q_vals, batch_cr)
    want = merge_ref(keys, ev, cr, vals, q_ids, q_ev, q_vals, batch_cr)
    for g, w, name in zip(got, want, ("event_ts", "creation_ts", "values")):
        np.testing.assert_array_equal(g, w, err_msg=name)


@pytest.mark.parametrize("slot_block", [128, 256, 1024])
def test_merge_slot_block_sweep(slot_block):
    rng = np.random.default_rng(slot_block)
    keys, ev, cr, vals, live = _build_table(rng, 2, 512, 400)
    q = 32
    q_ids = np.full((2, q), -2, np.int64)
    q_ev = np.zeros((2, q), np.int64)
    q_vals = np.zeros((2, q, 3), np.float32)
    part = partition_of(live, 2)
    for p in range(2):
        mine = live[part == p][:q]
        q_ids[p, : len(mine)] = mine
        q_ev[p, : len(mine)] = 5000  # all win
        q_vals[p, : len(mine)] = 7.0
    klo, khi = split_i64(keys)
    elo, ehi = split_i64(ev)
    clo, chi = split_i64(cr)
    qlo, qhi = split_i64(q_ids)
    qlo[q_ids == -2] = -2
    qhi[q_ids == -2] = -2
    qelo, qehi = split_i64(q_ev)
    cr_planes = np.asarray(
        np.concatenate(split_i64(np.asarray([6000]))), np.int32
    )
    out = merge(
        jnp.asarray(klo), jnp.asarray(khi), jnp.asarray(elo), jnp.asarray(ehi),
        jnp.asarray(clo), jnp.asarray(chi), jnp.asarray(vals),
        jnp.asarray(qlo), jnp.asarray(qhi), jnp.asarray(qelo), jnp.asarray(qehi),
        jnp.asarray(q_vals), jnp.asarray(cr_planes), slot_block=slot_block,
    )
    want = merge_ref(keys, ev, cr, vals, q_ids, q_ev, q_vals, 6000)
    np.testing.assert_array_equal(
        combine_i64(np.asarray(out[0]), np.asarray(out[1])), want[0]
    )
    np.testing.assert_array_equal(np.asarray(out[4]), want[2])


def test_route_flat_roundtrip():
    rng = np.random.default_rng(0)
    ids = rng.choice(np.arange(1, 10_000), size=200, replace=False).astype(np.int64)
    payload = rng.random((200, 4)).astype(np.float32)
    routed_ids, _, _, routed_payload = route_flat(8, ids, payload)
    # every id lands exactly once, in its hash partition
    flat = routed_ids[routed_ids != -2]
    assert sorted(flat.tolist()) == sorted(ids.tolist())
    part = partition_of(ids, 8)
    for j, _id in enumerate(ids):
        p = part[j]
        slot = np.flatnonzero(routed_ids[p] == _id)
        assert len(slot) == 1
        np.testing.assert_array_equal(routed_payload[p, slot[0]], payload[j])


def test_route_and_merge_empty_batch():
    keys = np.full((2, 8), -1, np.int64)
    klo, khi = split_i64(keys)
    ev = np.zeros((2, 8), np.int64)
    cr = np.zeros((2, 8), np.int64)
    vals = np.zeros((2, 8, 3), np.float32)
    ev_u, cr_u, vals_u = route_and_merge(
        klo, khi, ev, cr, vals, np.zeros(0, np.int64), np.zeros(0, np.int64),
        np.zeros((0, 3), np.float32), 100,
    )
    np.testing.assert_array_equal(ev_u, ev)
    np.testing.assert_array_equal(vals_u, vals)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_q=st.integers(1, 80))
def test_route_and_merge_property(seed, n_q):
    """Latest-wins invariant end-to-end: after the merge, every queried slot
    holds max((ev, cr), (q_ev, batch_cr)); untouched slots are unchanged."""
    rng = np.random.default_rng(seed)
    keys, ev, cr, vals, live = _build_table(rng, 4, 128, 300)
    klo, khi = split_i64(keys)
    pick = rng.choice(live, size=min(n_q, len(live)), replace=False)
    q_ev = rng.integers(0, 2000, len(pick)).astype(np.int64)
    q_vals = rng.random((len(pick), 3)).astype(np.float32)
    batch_cr = int(rng.integers(500, 2500))
    ev_u, cr_u, vals_u = route_and_merge(
        klo, khi, ev, cr, vals, pick, q_ev, q_vals, batch_cr
    )
    part = partition_of(pick, 4)
    touched = set()
    for j, pid in enumerate(pick):
        p = part[j]
        s = int(np.flatnonzero(keys[p] == pid)[0])
        touched.add((p, s))
        if (int(q_ev[j]), batch_cr) > (int(ev[p, s]), int(cr[p, s])):
            assert ev_u[p, s] == q_ev[j] and cr_u[p, s] == batch_cr
            np.testing.assert_array_equal(vals_u[p, s], q_vals[j])
        else:
            assert ev_u[p, s] == ev[p, s] and cr_u[p, s] == cr[p, s]
            np.testing.assert_array_equal(vals_u[p, s], vals[p, s])
    for p in range(4):
        for s in range(128):
            if (p, s) not in touched:
                assert ev_u[p, s] == ev[p, s]
