"""flash_attn kernel vs pure-jnp oracle: GQA/MQA shapes, dtypes, blocks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attn.ops import flash_attention, flash_bytes
from repro.kernels.flash_attn.ref import attention_ref


def _rand(b, s, h, kv, d, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, s, h, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, kv, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, kv, d)).astype(dtype)
    return q, k, v


@pytest.mark.parametrize(
    "b,s,h,kv,d",
    [
        (2, 128, 4, 2, 64),    # GQA
        (1, 128, 4, 1, 64),    # MQA
        (2, 64, 8, 8, 128),    # MHA, lane-width head
        (1, 64, 2, 1, 256),    # gemma-style 256 head_dim
    ],
)
def test_flash_matches_oracle(b, s, h, kv, d):
    q, k, v = _rand(b, s, h, kv, d)
    got = flash_attention(q, k, v, block_q=64, block_k=64)
    want = attention_ref(q, k, v)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bq,bk", [(32, 32), (32, 64), (64, 32), (128, 128)])
def test_flash_block_shapes(bq, bk):
    q, k, v = _rand(1, 128, 4, 2, 64, seed=3)
    got = flash_attention(q, k, v, block_q=bq, block_k=bk)
    want = attention_ref(q, k, v)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_flash_unaligned_seq_pads():
    q, k, v = _rand(1, 100, 4, 2, 64, seed=4)  # not a block multiple
    got = flash_attention(q, k, v, block_q=64, block_k=64)
    want = attention_ref(q, k, v)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_flash_bf16_inputs():
    q, k, v = _rand(1, 64, 4, 2, 64, dtype=jnp.bfloat16, seed=5)
    got = flash_attention(q, k, v, block_q=32, block_k=32)
    want = attention_ref(q, k, v)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want, rtol=2e-2, atol=2e-2
    )


def test_flash_causality():
    """Changing a future key/value must not change past outputs."""
    q, k, v = _rand(1, 64, 2, 1, 32, seed=6)
    out1 = flash_attention(q, k, v, block_q=32, block_k=32)
    k2 = k.at[:, 40:].set(99.0)
    v2 = v.at[:, 40:].set(-99.0)
    out2 = flash_attention(q, k2, v2, block_q=32, block_k=32)
    np.testing.assert_allclose(out1[:, :40], out2[:, :40], rtol=1e-6)
    assert not np.allclose(out1[:, 41:], out2[:, 41:])


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    s=st.sampled_from([32, 48, 96, 160]),
    h=st.sampled_from([1, 2, 4]),
)
def test_flash_property(seed, s, h):
    q, k, v = _rand(1, s, h, 1, 32, seed=seed % 1000)
    got = flash_attention(q, k, v, block_q=32, block_k=32)
    want = attention_ref(q, k, v)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_flash_bytes_model_sublinear():
    """The analytic traffic model must be O(S·D)-ish, not O(S²): doubling S
    at fixed block count scales bytes ~4x for scores-in-HBM but ~2-3x for
    flash (K/V re-streamed per q-block)."""
    b1 = flash_bytes(1, 4096, 4096, 32, 8, 128)
    b2 = flash_bytes(1, 8192, 8192, 32, 8, 128)
    naive1 = 4 * 32 * 4096 * 4096  # score bytes alone, f32
    naive2 = 4 * 32 * 8192 * 8192
    assert b2 / b1 < 4.2
    assert b1 < naive1 and b2 < naive2


def test_flash_integrates_with_model_attention():
    """cfg.attn_impl='pallas_flash' must match the xla attention path."""
    import dataclasses

    from repro.models import attention as A
    from repro.models.config import ModelConfig

    cfg = ModelConfig(
        name="t", family="dense", num_layers=1, d_model=64, vocab_size=32,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        param_dtype="float32", compute_dtype="float32",
    )
    params = A.attn_init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64))
    pos = jnp.arange(64, dtype=jnp.int32)
    y_xla = A.attention(params, x, pos, cfg)
    cfg_f = dataclasses.replace(cfg, attn_impl="pallas_flash")
    y_flash = A.attention(params, x, pos, cfg_f)
    np.testing.assert_allclose(y_flash, y_xla, rtol=2e-5, atol=2e-5)
