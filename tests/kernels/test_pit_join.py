"""pit_join counting-search kernel vs pure-jnp oracle + brute force."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.pit_join.ops import pit_search
from repro.kernels.pit_join.ref import pit_search_ref


def _make_table(rng, n_seg, max_rows):
    """Segmented table: rows sorted by ts within each segment."""
    seg_sizes = rng.integers(0, max_rows, size=n_seg)
    table_ts = []
    bounds = []
    off = 0
    for sz in seg_sizes:
        ts = np.sort(rng.integers(0, 1000, size=sz))
        table_ts.append(ts)
        bounds.append((off, off + sz))
        off += sz
    table = np.concatenate(table_ts) if table_ts else np.zeros(0, np.int64)
    return table.astype(np.int32), bounds


def _make_queries(rng, bounds, n_q):
    segs = rng.integers(0, len(bounds), size=n_q)
    q_lo = np.array([bounds[s][0] for s in segs], np.int32)
    q_hi = np.array([bounds[s][1] for s in segs], np.int32)
    q_ts = rng.integers(-50, 1100, size=n_q).astype(np.int32)
    return q_ts, q_lo, q_hi


def _brute(table, q_ts, q_lo, q_hi):
    idx = np.full(len(q_ts), -1, np.int64)
    valid = np.zeros(len(q_ts), bool)
    for i, (t, lo, hi) in enumerate(zip(q_ts, q_lo, q_hi)):
        cand = [r for r in range(lo, hi) if table[r] <= t]
        if cand:
            idx[i] = max(cand)
            valid[i] = True
    return idx, valid


@pytest.mark.parametrize("n_seg,max_rows,n_q", [(1, 50, 17), (5, 200, 300), (20, 30, 64)])
def test_pit_search_vs_brute(n_seg, max_rows, n_q):
    rng = np.random.default_rng(n_seg * 100 + n_q)
    table, bounds = _make_table(rng, n_seg, max_rows)
    q_ts, q_lo, q_hi = _make_queries(rng, bounds, n_q)
    idx, valid = pit_search(
        jnp.asarray(table), jnp.asarray(q_ts), jnp.asarray(q_lo), jnp.asarray(q_hi)
    )
    b_idx, b_valid = _brute(table, q_ts, q_lo, q_hi)
    np.testing.assert_array_equal(np.asarray(valid), b_valid)
    np.testing.assert_array_equal(np.asarray(idx)[b_valid], b_idx[b_valid])


@pytest.mark.parametrize("q_block,rows", [(512, 8), (128, 8), (512, 16), (256, 32)])
def test_pit_search_tilings(q_block, rows):
    rng = np.random.default_rng(q_block + rows)
    table, bounds = _make_table(rng, 6, 300)
    q_ts, q_lo, q_hi = _make_queries(rng, bounds, 200)
    idx, valid = pit_search(
        jnp.asarray(table), jnp.asarray(q_ts), jnp.asarray(q_lo), jnp.asarray(q_hi),
        q_block=q_block, table_rows_per_block=rows,
    )
    ref_idx, ref_valid = pit_search_ref(
        jnp.asarray(table), jnp.asarray(q_ts), jnp.asarray(q_lo), jnp.asarray(q_hi)
    )
    np.testing.assert_array_equal(np.asarray(valid), np.asarray(ref_valid))
    v = np.asarray(ref_valid)
    np.testing.assert_array_equal(np.asarray(idx)[v], np.asarray(ref_idx)[v])


def test_pit_search_empty_table_and_empty_segments():
    # all-empty segments: hi == lo
    table = jnp.asarray(np.zeros(0, np.int32))
    q = jnp.asarray(np.array([5, 10], np.int32))
    z = jnp.asarray(np.zeros(2, np.int32))
    idx, valid = pit_search(table, q, z, z)
    assert not np.asarray(valid).any()


def test_pit_search_exact_timestamp_is_inclusive():
    """'nearest past' includes a record AT the observation time (<=)."""
    table = jnp.asarray(np.array([10, 20, 30], np.int32))
    q_ts = jnp.asarray(np.array([20], np.int32))
    lo = jnp.asarray(np.array([0], np.int32))
    hi = jnp.asarray(np.array([3], np.int32))
    idx, valid = pit_search(table, q_ts, lo, hi)
    assert bool(valid[0]) and int(idx[0]) == 1


def test_pit_search_no_future_leak():
    """A query strictly before every record must be invalid — the §4.4
    leakage guarantee at the kernel level."""
    table = jnp.asarray(np.array([100, 200], np.int32))
    idx, valid = pit_search(
        table,
        jnp.asarray(np.array([99], np.int32)),
        jnp.asarray(np.array([0], np.int32)),
        jnp.asarray(np.array([2], np.int32)),
    )
    assert not bool(valid[0])


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_seg=st.integers(1, 8),
    n_q=st.integers(1, 300),
)
def test_pit_search_property(seed, n_seg, n_q):
    rng = np.random.default_rng(seed)
    table, bounds = _make_table(rng, n_seg, 120)
    q_ts, q_lo, q_hi = _make_queries(rng, bounds, n_q)
    idx, valid = pit_search(
        jnp.asarray(table), jnp.asarray(q_ts), jnp.asarray(q_lo), jnp.asarray(q_hi)
    )
    idx, valid = np.asarray(idx), np.asarray(valid)
    # properties: result in segment, ts <= query ts, and next row (if any) > ts
    for i in range(n_q):
        if valid[i]:
            r = idx[i]
            assert q_lo[i] <= r < q_hi[i]
            assert table[r] <= q_ts[i]
            if r + 1 < q_hi[i]:
                assert table[r + 1] > q_ts[i]
        else:
            in_seg = table[q_lo[i] : q_hi[i]]
            assert (in_seg > q_ts[i]).all() or len(in_seg) == 0
