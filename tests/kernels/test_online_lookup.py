"""online_lookup kernel vs oracle: routing, padding, sentinel handling."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.online_lookup.ops import (
    lookup,
    partition_of,
    route_and_lookup,
    split_i64,
)
from repro.kernels.online_lookup.ref import lookup_ref


def _build_store(rng, num_p, cap, n_live, dim=4):
    ids = rng.choice(np.arange(1, 10_000_000), size=n_live, replace=False).astype(
        np.int64
    )
    keys_lo = np.full((num_p, cap), -1, np.int32)
    keys_hi = np.full((num_p, cap), -1, np.int32)
    values = np.zeros((num_p, cap, dim), np.float32)
    part = partition_of(ids, num_p)
    lo, hi = split_i64(ids)
    fill = np.zeros(num_p, np.int64)
    kept = []
    for j in range(n_live):
        p = part[j]
        if fill[p] >= cap:
            continue
        keys_lo[p, fill[p]] = lo[j]
        keys_hi[p, fill[p]] = hi[j]
        values[p, fill[p]] = float(ids[j] % 97)
        fill[p] += 1
        kept.append(ids[j])
    return keys_lo, keys_hi, values, np.array(kept, np.int64)


def test_split_i64_roundtrip():
    ids = np.array([0, 1, 2**31, 2**40 + 17, -5, np.iinfo(np.int64).max], np.int64)
    lo, hi = split_i64(ids)
    rebuilt = (
        lo.view(np.uint32).astype(np.uint64)
        | (hi.view(np.uint32).astype(np.uint64) << np.uint64(32))
    ).view(np.int64)
    np.testing.assert_array_equal(rebuilt, ids)


def test_partition_routing_stable_and_in_range():
    ids = np.arange(1, 5000, dtype=np.int64)
    p1 = partition_of(ids, 16)
    p2 = partition_of(ids, 16)
    np.testing.assert_array_equal(p1, p2)
    assert p1.min() >= 0 and p1.max() < 16
    # reasonable balance for the Fibonacci mix: no partition > 3x the mean
    counts = np.bincount(p1, minlength=16)
    assert counts.max() < 3 * counts.mean()


@pytest.mark.parametrize("num_p,cap,q", [(1, 64, 16), (4, 1024, 100), (8, 100, 7)])
def test_lookup_vs_ref(num_p, cap, q):
    rng = np.random.default_rng(num_p * cap + q)
    keys_lo = rng.integers(0, 2**31 - 1, size=(num_p, cap)).astype(np.int32)
    keys_hi = rng.integers(0, 100, size=(num_p, cap)).astype(np.int32)
    # half the queries hit, half miss
    q_lo = np.full((num_p, q), -2, np.int32)
    q_hi = np.full((num_p, q), -2, np.int32)
    for p in range(num_p):
        for i in range(q):
            if rng.random() < 0.5:
                c = rng.integers(0, cap)
                q_lo[p, i] = keys_lo[p, c]
                q_hi[p, i] = keys_hi[p, c]
            else:
                q_lo[p, i] = rng.integers(0, 2**31 - 1)
                q_hi[p, i] = 101  # plane-2 value no live key uses
    got = lookup(
        jnp.asarray(keys_lo), jnp.asarray(keys_hi), jnp.asarray(q_lo), jnp.asarray(q_hi)
    )
    want = lookup_ref(
        jnp.asarray(keys_lo), jnp.asarray(keys_hi), jnp.asarray(q_lo), jnp.asarray(q_hi)
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("slot_block", [128, 256, 1024])
def test_lookup_slot_block_sweep(slot_block):
    rng = np.random.default_rng(slot_block)
    num_p, cap, q = 2, 512, 64
    keys_lo = rng.integers(0, 1000, size=(num_p, cap)).astype(np.int32)
    keys_hi = np.zeros((num_p, cap), np.int32)
    q_lo = keys_lo[:, :q].copy()
    q_hi = np.zeros((num_p, q), np.int32)
    got = lookup(
        jnp.asarray(keys_lo), jnp.asarray(keys_hi),
        jnp.asarray(q_lo), jnp.asarray(q_hi), slot_block=slot_block,
    )
    want = lookup_ref(
        jnp.asarray(keys_lo), jnp.asarray(keys_hi), jnp.asarray(q_lo), jnp.asarray(q_hi)
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_route_and_lookup_end_to_end():
    rng = np.random.default_rng(3)
    keys_lo, keys_hi, values, live = _build_store(rng, 8, 256, 900)
    hits = rng.choice(live, size=50, replace=False)
    misses = np.arange(20_000_000, 20_000_030, dtype=np.int64)
    ids = np.concatenate([hits, misses])
    rng.shuffle(ids)
    out, found = route_and_lookup(keys_lo, keys_hi, values, ids)
    for i, _id in enumerate(ids):
        if _id in set(live.tolist()):
            assert found[i], _id
            np.testing.assert_allclose(out[i], float(_id % 97))
        else:
            assert not found[i]
            np.testing.assert_allclose(out[i], 0.0)


def test_route_and_lookup_empty_batch():
    keys_lo = np.full((2, 8), -1, np.int32)
    out, found = route_and_lookup(
        keys_lo, keys_lo.copy(), np.zeros((2, 8, 3), np.float32), np.zeros(0, np.int64)
    )
    assert out.shape == (0, 3) and found.shape == (0,)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_q=st.integers(1, 120))
def test_route_and_lookup_property(seed, n_q):
    """Every id stored must be found with its value; ids never stored must
    miss.  (Exactly Algorithm-2 GET semantics over the partitioned mirror.)"""
    rng = np.random.default_rng(seed)
    keys_lo, keys_hi, values, live = _build_store(rng, 4, 128, 300)
    live_set = set(live.tolist())
    universe = np.concatenate([live, rng.integers(10**8, 10**9, size=50)])
    ids = rng.choice(universe, size=n_q)
    out, found = route_and_lookup(keys_lo, keys_hi, values, ids)
    for i, _id in enumerate(ids):
        assert found[i] == (_id in live_set)
        if found[i]:
            np.testing.assert_allclose(out[i], float(_id % 97))
