"""Benchmark: §2.1/§3.1.4 online retrieval latency + §4.5 merge throughput.

  * GET: batched lookups/s and per-request latency percentiles against the
    partitioned online store (XLA compare-match path; the Pallas kernel is
    the TPU lowering of the same plan, validated in tests)
  * MERGE (Algorithm 2): records/s merged into the online store, including
    the stale-update no-op path (idempotence under retries)
  * MERGE ENGINES: the per-row loop reference vs the vectorized engine vs
    the kernels/online_merge Pallas path, same workload, rows/s each
  * staleness metric: the §2.1 freshness SLA readout under a materialization
    cadence
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.assets import Entity, Feature, FeatureSetSpec, MaterializationSettings
from repro.core.dsl import DslTransform, RollingAgg, UDFTransform
from repro.core.featurestore import FeatureStore
from repro.core.online_store import OnlineStore
from repro.core.table import Table
from repro.data.sources import SyntheticEventSource

HOUR = 3_600_000


def bench_merge_engines(rows: int = 50_000, batches: int = 5) -> dict:
    """Online-store Algorithm-2 merge rows/s per write engine (same data,
    byte-identical end states — parity is covered by tests/core)."""
    spec = FeatureSetSpec(
        name="m", version=1, entity=Entity("customer", ("entity_id",)),
        features=(Feature("f0", "float32"),), source_name="direct",
        transform=UDFTransform(lambda df, ctx: df, name="id"),
        timestamp_col="ts",
        materialization=MaterializationSettings(True, True),
    )
    per_batch = rows // batches
    out = {}
    for engine in ("loop", "vector", "kernel"):
        rng = np.random.default_rng(3)
        store = OnlineStore(merge_engine=engine)
        frames = [
            Table({
                "entity_id": rng.integers(0, 10_000, per_batch).astype(np.int64),
                "ts": rng.integers(0, 10**6 * (i + 1), per_batch).astype(np.int64),
                "f0": rng.random(per_batch).astype(np.float32),
            })
            for i in range(batches)
        ]
        store.merge(spec, frames[0], 10**7)  # warm (jit for the kernel path)
        t0 = time.perf_counter()
        for i, f in enumerate(frames):
            store.merge(spec, f, 10**8 + i)
        wall = time.perf_counter() - t0
        out[engine] = {
            "rows_per_s": int(rows / wall),
            "wall_s": round(wall, 4),
            "counters": {
                "inserts": store.inserts,
                "overrides": store.overrides,
                "noops": store.noops,
            },
        }
    return out


def _store(entities: int, hours: int = 8) -> FeatureStore:
    fs = FeatureStore("bench-online", interpret=True)
    src = SyntheticEventSource("tx", num_entities=entities, events_per_bucket=600)
    fs.register_source(src)
    fs.create_feature_set(
        FeatureSetSpec(
            name="act", version=1,
            entity=Entity("customer", ("entity_id",)),
            features=(Feature("s2", "float32"),),
            source_name="tx",
            transform=DslTransform("entity_id", "ts",
                                   [RollingAgg("s2", "amount", 2 * HOUR, "sum")]),
            timestamp_col="ts", source_lookback=2 * HOUR,
            materialization=MaterializationSettings(
                offline_enabled=True, online_enabled=True, schedule_interval=HOUR
            ),
        )
    )
    fs.tick(now=hours * HOUR)
    return fs


def run(entity_counts=(1_000, 10_000), batch=256, rounds=20) -> dict:
    rows = []
    for n_ent in entity_counts:
        fs = _store(n_ent)
        rng = np.random.default_rng(1)
        lat = []
        hits = 0
        for _ in range(rounds):
            ids = rng.integers(0, n_ent, batch).astype(np.int64)
            t0 = time.perf_counter()
            vals, found = fs.get_online_features("act", 1, [ids], use_kernel=False)
            lat.append((time.perf_counter() - t0) * 1e3)
            hits += int(found.sum())
        lat = np.array(lat[1:])  # drop cold call
        rows.append({
            "entities": n_ent,
            "batch": batch,
            "lookups_per_s": int(batch / (lat.mean() / 1e3)),
            "batch_ms_p50": round(float(np.percentile(lat, 50)), 3),
            "batch_ms_p99": round(float(np.percentile(lat, 99)), 3),
            "hit_rate": round(hits / (batch * rounds), 3),
        })

    # -- merge throughput + idempotence (Algorithm 2) ---------------------------
    fs = _store(5_000, hours=4)
    online = fs.online
    spec = fs.registry.get_feature_set("act", 1)
    t0 = time.perf_counter()
    stats = fs.tick(now=8 * HOUR)  # four more hours of merges
    merge_s = time.perf_counter() - t0
    n_rows = len(fs.offline.read("act", 1))

    # staleness SLA metric
    snap = fs.monitor.system.snapshot()
    stale = snap["gauges"].get("staleness_ms/act:v1", None)

    return {
        "lookup_table": rows,
        "merge": {
            "rows_in_store": n_rows,
            "tick_wall_s": round(merge_s, 3),
            "jobs": stats,
        },
        "merge_engines": bench_merge_engines(),
        "staleness_ms": stale,
    }


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
