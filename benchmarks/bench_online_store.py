"""Benchmark: §2.1/§3.1.4 online retrieval latency + §4.5 merge throughput.

  * GET: batched lookups/s and per-request latency percentiles for BOTH
    serving paths — host mirror (numpy compare-match) and the device-resident
    kernel path (Pallas scan over resident key planes + on-device row
    gather), steady-state post-warmup
  * MERGE (Algorithm 2): records/s merged into the online store, including
    the stale-update no-op path (idempotence under retries)
  * MERGE ENGINES: the per-row loop reference vs the vectorized engine vs
    the device-resident kernel path, same workload, rows/s each
  * RESIDENT CYCLE: host<->device bytes a steady merge+lookup cycle moves —
    GUARDED: raises if the serving path regresses to table-sized (O(P·C·D))
    traffic, so the tier-1 bench smoke fails instead of silently eroding
  * staleness metric: the §2.1 freshness SLA readout under a materialization
    cadence
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.assets import Entity, Feature, FeatureSetSpec, MaterializationSettings
from repro.core.dsl import DslTransform, RollingAgg, UDFTransform
from repro.core.featurestore import FeatureStore
from repro.core.online_store import OnlineStore, o_batch_byte_budget
from repro.core.table import Table
from repro.data.sources import SyntheticEventSource

HOUR = 3_600_000


def bench_merge_engines(rows: int = 50_000, batches: int = 5) -> dict:
    """Online-store Algorithm-2 merge rows/s per write engine (same data,
    byte-identical end states — parity is covered by tests/core)."""
    spec = FeatureSetSpec(
        name="m", version=1, entity=Entity("customer", ("entity_id",)),
        features=(Feature("f0", "float32"),), source_name="direct",
        transform=UDFTransform(lambda df, ctx: df, name="id"),
        timestamp_col="ts",
        materialization=MaterializationSettings(True, True),
    )
    per_batch = rows // batches
    out = {}
    for engine in ("loop", "vector", "kernel"):
        rng = np.random.default_rng(3)
        store = OnlineStore(merge_engine=engine)
        frames = [
            Table(
                {
                    "entity_id": rng.integers(0, 10_000, per_batch).astype(np.int64),
                    "ts": rng.integers(0, 10**6 * (i + 1), per_batch).astype(np.int64),
                    "f0": rng.random(per_batch).astype(np.float32),
                }
            )
            for i in range(batches)
        ]
        # steady-state warmup: insert EVERY id once so capacity growth, jit
        # traces, and the device upload all land off the clock — the timed
        # merges then exercise the resident override/no-op hot path
        warm = Table(
            {
                "entity_id": np.arange(10_000, dtype=np.int64),
                "ts": np.zeros(10_000, np.int64),
                "f0": np.zeros(10_000, np.float32),
            }
        )
        store.merge(spec, warm, 10**6)
        store.merge(spec, frames[0], 10**7)  # warm the per-batch jit shapes
        base = (store.inserts, store.overrides, store.noops)
        t0 = time.perf_counter()
        for i, f in enumerate(frames):
            store.merge(spec, f, 10**8 + i)
        wall = time.perf_counter() - t0
        out[engine] = {
            "rows_per_s": int(rows / wall),
            "wall_s": round(wall, 4),
            # timed-workload deltas only — warmup merges stay off the books
            "counters": {
                "inserts": store.inserts - base[0],
                "overrides": store.overrides - base[1],
                "noops": store.noops - base[2],
            },
        }
    return out


def _store(entities: int, hours: int = 8) -> FeatureStore:
    fs = FeatureStore("bench-online", interpret=True)
    src = SyntheticEventSource("tx", num_entities=entities, events_per_bucket=600)
    fs.register_source(src)
    fs.create_feature_set(
        FeatureSetSpec(
            name="act", version=1,
            entity=Entity("customer", ("entity_id",)),
            features=(Feature("s2", "float32"),),
            source_name="tx",
            transform=DslTransform("entity_id", "ts",
                                   [RollingAgg("s2", "amount", 2 * HOUR, "sum")]),
            timestamp_col="ts", source_lookback=2 * HOUR,
            materialization=MaterializationSettings(
                offline_enabled=True, online_enabled=True, schedule_interval=HOUR
            ),
        )
    )
    fs.tick(now=hours * HOUR)
    return fs


def _bench_get_path(fs, n_ent, batch, rounds, *, use_kernel) -> dict:
    """Steady-state GET: one warmup round (jit + device upload off the
    clock), then ``rounds`` timed batches."""
    rng = np.random.default_rng(1)
    fs.get_online_features(
        "act", 1, [rng.integers(0, n_ent, batch).astype(np.int64)],
        use_kernel=use_kernel,
    )
    lat = []
    hits = 0
    for _ in range(rounds):
        ids = rng.integers(0, n_ent, batch).astype(np.int64)
        t0 = time.perf_counter()
        _, found = fs.get_online_features("act", 1, [ids], use_kernel=use_kernel)
        lat.append((time.perf_counter() - t0) * 1e3)
        hits += int(found.sum())
    lat = np.array(lat)
    return {
        "lookups_per_s": int(batch / (lat.mean() / 1e3)),
        "batch_ms_p50": round(float(np.percentile(lat, 50)), 3),
        "batch_ms_p99": round(float(np.percentile(lat, 99)), 3),
        "hit_rate": round(hits / (batch * rounds), 3),
    }


def _resident_cycle(entities=20_000, batch=2_048, cycles=10) -> dict:
    """Steady-state merge+lookup cycle traffic on the device-resident path.

    Raises RuntimeError when the cycle re-uploads the table, pulls the host
    mirror, or moves more than an O(batch) byte budget — the transfer
    regression guard wired into tier-1 via ``benchmarks/run.py --fast``."""
    spec = FeatureSetSpec(
        name="m", version=1, entity=Entity("customer", ("entity_id",)),
        features=(Feature("f0", "float32"),), source_name="direct",
        transform=UDFTransform(lambda df, ctx: df, name="id"),
        timestamp_col="ts",
        materialization=MaterializationSettings(True, True),
    )
    rng = np.random.default_rng(5)
    store = OnlineStore(merge_engine="kernel")

    def frame(n, t0):
        return Table(
            {
                "entity_id": rng.integers(0, entities, n).astype(np.int64),
                "ts": (t0 + rng.integers(0, 10**6, n)).astype(np.int64),
                "f0": rng.random(n).astype(np.float32),
            }
        )

    store.merge(spec, frame(entities * 2, 0), 10**7)  # build + grow
    ids = [rng.integers(0, entities, 256).astype(np.int64)]
    store.merge(spec, frame(batch, 10**6), 10**7 + 1)  # warm merge shapes
    store.lookup("m", 1, ids)  # warm lookup shapes
    store.reset_transfer_stats()
    t0 = time.perf_counter()
    for i in range(cycles):
        store.merge(spec, frame(batch, 10**6 * (i + 2)), 10**8 + i)
        store.lookup("m", 1, ids)
    wall = time.perf_counter() - t0
    tx = store.transfer_stats()
    table_bytes = store.device_state("m", 1).nbytes()
    per_cycle = (tx["h2d_bytes"] + tx["d2h_bytes"]) / cycles
    budget = o_batch_byte_budget(batch, record_bytes=8 * 4 + 4)
    if tx["device_uploads"] or tx["host_syncs"]:
        raise RuntimeError(
            f"resident cycle re-moved the table: {tx} (transfer regression)"
        )
    if per_cycle > budget or per_cycle > table_bytes / 4:
        raise RuntimeError(
            f"resident cycle moves {per_cycle:.0f} B (budget {budget}, "
            f"table {table_bytes}) — serving path transfer regression"
        )
    return {
        "batch": batch,
        "cycles": cycles,
        "per_cycle_bytes": int(per_cycle),
        "table_bytes": int(table_bytes),
        "table_to_cycle_ratio_x": round(table_bytes / max(per_cycle, 1), 1),
        "cycle_ms": round(wall / cycles * 1e3, 3),
        "transfers": tx,
    }


def run(entity_counts=(1_000, 10_000), batch=256, rounds=20) -> dict:
    rows = []
    for n_ent in entity_counts:
        fs = _store(n_ent)
        row = {"entities": n_ent, "batch": batch}
        for path, use_kernel in (("host", False), ("kernel", True)):
            row[path] = _bench_get_path(
                fs, n_ent, batch, rounds, use_kernel=use_kernel
            )
        # steady-state GET traffic guard: resident kernel lookups must not
        # re-upload the table or sync the mirror
        fs.online.reset_transfer_stats()
        _bench_get_path(fs, n_ent, batch, 5, use_kernel=True)
        tx = fs.online.transfer_stats()
        if tx["device_uploads"] or tx["host_syncs"]:
            raise RuntimeError(f"kernel GET path re-moved the table: {tx}")
        row["kernel_get_bytes_per_batch"] = int(
            (tx["h2d_bytes"] + tx["d2h_bytes"]) / 6  # 5 rounds + warmup
        )
        rows.append(row)

    # -- merge throughput + idempotence (Algorithm 2) ---------------------------
    fs = _store(5_000, hours=4)
    online = fs.online
    spec = fs.registry.get_feature_set("act", 1)
    t0 = time.perf_counter()
    stats = fs.tick(now=8 * HOUR)  # four more hours of merges
    merge_s = time.perf_counter() - t0
    n_rows = len(fs.offline.read("act", 1))

    # staleness SLA metric
    snap = fs.monitor.system.snapshot()
    stale = snap["gauges"].get("staleness_ms/act:v1", None)

    return {
        "lookup_table": rows,
        "merge": {
            "rows_in_store": n_rows,
            "tick_wall_s": round(merge_s, 3),
            "jobs": stats,
        },
        "merge_engines": bench_merge_engines(),
        "resident_cycle": _resident_cycle(),
        "staleness_ms": stale,
    }


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
