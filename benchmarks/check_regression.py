"""Bench-regression gate: fail CI when the serving path gets slower.

Compares the tier-1 bench smoke's output (``results/bench_fast.json``,
written by ``benchmarks/run.py --fast --only
online_store,geo_replication,serving``) against the committed trajectory
artifacts ``BENCH_online_store.json``, ``BENCH_geo_replication.json`` and
``BENCH_serving.json``.  Classes of check:

* TRANSFER / SHIPPED BYTES (deterministic): the device-resident protocol's
  steady-state byte counts and the geo replicator's per-plane shipped-byte
  counts are a function of workload shapes, not machine speed — resident
  merge+lookup cycles must not move more bytes per cycle than the
  committed baseline, must never re-upload the table or sync the host
  mirror, kernel GETs must not grow their per-batch traffic, and the geo
  throughput bench's online/offline shipped bytes must match the
  committed numbers EXACTLY (its workload is seeded and fixed-shape even
  under --fast; a mismatch means the wire format or reduction changed and
  the baseline must be re-committed deliberately).  Since the wire
  transport landed (core/wire.py) the gated geo numbers are TRUE wire
  bytes: raw serialized payload AND post-zlib frame size per plane —
  deliberately re-baselined in BENCH_geo_replication.json for the wire
  format (the pre-wire numbers were array-size estimates).  The
  compressed sizes assume the standard zlib deflate output CPython links
  everywhere we run; a wire-byte mismatch with identical raw bytes means
  the compression layer changed, not the workload.

* CHAOS CONVERGENCE (deterministic + calibrated, ISSUE 7): the chaos
  section of the geo bench pushes the same two-plane workload through a
  seeded ``FaultyChannel`` (10% drop + lower-rate dup/reorder/corrupt/
  ack-loss/spike) and a logical-tick delivery state machine, so every
  count it reports — drain rounds, retried batches, timeouts, CRC-rejected
  frames, redeliveries, per-kind channel injections, retry amplification,
  shipped bytes — is a pure function of the two seeds and must match the
  committed baseline EXACTLY; a drift means the fault schedule, backoff
  policy, or retry semantics changed and the artifact must be re-committed
  deliberately.  The convergence/recovery booleans (both planes
  byte-identical after the faults; the partition scenario's DEAD detection
  drove ``topology.mark_down`` and probe recovery brought the link back)
  are re-asserted fresh on every run.  Only ``goodput_rows_per_s`` is
  wall-clock, gated within the calibrated tolerance.

* MERGE / APPLY THROUGHPUT (tolerance + calibration): rows/s is machine-
  and load-dependent, so the committed baseline is first rescaled by how
  fast THIS run's ``loop`` reference engine is relative to the baseline's
  — the per-row loop runs the same code in both runs, making it a cheap
  machine-speed probe.  The ``vector`` and ``kernel`` merge engines and
  the geo replica-apply rates (both planes) must then stay within
  ``--tolerance`` (default 30%) of the calibrated baseline, and ``vector``
  must remain faster than ``loop`` outright (the vectorization win is
  machine-independent).

Runs locally from ``scripts/tier1.sh`` after the bench smoke, and as a
dedicated CI step.  Exit code 1 on any regression.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path


def load_suite_result(path: Path, suite_name: str) -> dict:
    """Accept either a benchmarks/run.py output file (suite wrapper) or a
    flat trajectory artifact."""
    data = json.loads(path.read_text())
    if suite_name in data:
        suite = data[suite_name]
        if not suite.get("ok"):
            raise SystemExit(f"{path}: {suite_name} suite failed: {suite}")
        return suite["result"]
    return data


def require_phase(result: dict, phase: str, *, source: str) -> dict | list:
    """The one accessor for every bench-phase extraction in this gate.

    A missing phase means the bench section that produces it silently
    stopped running upstream — exactly the vacuous-pass failure mode this
    gate exists to prevent (PR 8: the gate passed whenever its input
    artifact was missing).  A bare ``result[phase]`` dies with an opaque
    KeyError; ``result.get(phase, {})`` quietly gates nothing.  This fails
    loudly, names the gap, and refuses to proceed."""
    if phase not in result:
        present = ", ".join(sorted(result)) or "<empty>"
        raise SystemExit(
            f"{source}: bench phase {phase!r} is missing (present: {present}) "
            f"— the section that produces it did not run; refusing to gate "
            f"vacuously"
        )
    section = result[phase]
    if not isinstance(section, (dict, list)):
        raise SystemExit(
            f"{source}: bench phase {phase!r} is {type(section).__name__}, "
            f"not a mapping/sequence — the bench output format changed "
            f"under the gate"
        )
    return section


def check_transfer_bytes(cur: dict, base: dict, failures: list[str]) -> None:
    c = require_phase(cur, "resident_cycle", source="current")
    b = require_phase(base, "resident_cycle", source="baseline")
    tx = c["transfers"]
    if tx["device_uploads"] or tx["host_syncs"]:
        failures.append(f"resident cycle re-moved the table: {tx}")
    cyc, cyc_base = c["per_cycle_bytes"], b["per_cycle_bytes"]
    if cyc > cyc_base:
        failures.append(f"transfer bytes regressed: {cyc} B/cycle vs {cyc_base}")
    else:
        print(f"  ok: resident cycle {cyc} B/cycle (committed {cyc_base})")
    base_rows = {}
    for r in require_phase(base, "lookup_table", source="baseline"):
        base_rows[(r["entities"], r["batch"])] = r["kernel_get_bytes_per_batch"]
    for row in require_phase(cur, "lookup_table", source="current"):
        key = (row["entities"], row["batch"])
        if key not in base_rows:
            continue
        got, want = row["kernel_get_bytes_per_batch"], base_rows[key]
        if got > want:
            failures.append(f"kernel GET bytes regressed at {key}: {got} vs {want}")
        else:
            print(f"  ok: kernel GET {got} B/batch at {key} (committed {want})")


def check_merge_throughput(
    cur: dict, base: dict, tolerance: float, failures: list[str]
) -> float:
    """Gate the merge engines; returns the machine-speed calibration scale
    (this run's loop reference vs the baseline's) for downstream gates."""
    c = require_phase(cur, "merge_engines", source="current")
    b = require_phase(base, "merge_engines", source="baseline")
    cur_loop = c["loop"]["rows_per_s"]
    base_loop = b["loop"]["rows_per_s"]
    scale = min(1.0, cur_loop / base_loop)
    print(f"  calibration: loop {cur_loop}/{base_loop} rows/s -> scale {scale:.2f}")
    for engine in ("vector", "kernel"):
        got = c[engine]["rows_per_s"]
        floor = int(b[engine]["rows_per_s"] * scale * (1.0 - tolerance))
        if got < floor:
            msg = f"{engine} merge dropped >{tolerance:.0%}: {got} rows/s vs {floor}"
            failures.append(msg)
        else:
            print(f"  ok: {engine} {got} rows/s (calibrated floor {floor})")
    vec = c["vector"]["rows_per_s"]
    if vec < cur_loop:
        failures.append(f"vector ({vec} rows/s) fell behind loop ({cur_loop} rows/s)")
    return scale


def check_geo_replication(
    cur: dict, base: dict, tolerance: float, scale: float, failures: list[str]
) -> None:
    """Offline+online plane gates for the geo replicator (ISSUE 4, wire
    bytes since ISSUE 5): raw AND compressed wire bytes exactly (the
    throughput workload is seeded and fixed-shape, so any drift is a
    wire-format/reduction/compression change that must be re-committed
    deliberately); the recorded compression ratio must not regress below
    break-even; replica-apply rows/s within the machine-calibrated
    tolerance, per plane."""
    c = require_phase(cur, "throughput", source="current geo")
    b = require_phase(base, "throughput", source="baseline geo")
    byte_fields = (
        "shipped_bytes",
        "shipped_raw_bytes",
        "offline_shipped_bytes",
        "offline_shipped_raw_bytes",
    )
    for field in byte_fields:
        got, want = c[field], b[field]
        if got != want:
            failures.append(
                f"geo {field} drifted: {got} vs committed {want} "
                f"(re-commit BENCH_geo_replication.json if intentional)"
            )
        else:
            print(f"  ok: geo {field} {got} B (exact match)")
    ratio = c["compression_ratio"]
    if ratio < 1.0:
        failures.append(
            f"geo wire compression fell below break-even: ratio {ratio} "
            f"(encoder should ship raw when zlib does not win)"
        )
    else:
        print(
            f"  ok: geo wire compression ratio {ratio} (committed "
            f"{b['compression_ratio']})"
        )
    for field in ("replica_apply_rows_per_s", "offline_apply_rows_per_s"):
        got = c[field]
        floor = int(b[field] * scale * (1.0 - tolerance))
        if got < floor:
            failures.append(
                f"geo {field} dropped >{tolerance:.0%}: {got} rows/s vs {floor}"
            )
        else:
            print(f"  ok: geo {field} {got} rows/s (calibrated floor {floor})")
    for field in ("replica_state_identical", "offline_state_identical"):
        if not c.get(field):
            failures.append(f"geo {field} is no longer asserted true")


def check_chaos(
    cur: dict, base: dict, tolerance: float, scale: float, failures: list[str]
) -> None:
    """Chaos-convergence gates (ISSUE 7).  Everything the fault-injected
    drain loop counts is seeded + logical-tick deterministic, so it is
    gated EXACTLY; the convergence/recovery booleans are re-asserted
    fresh; only goodput is wall-clock (calibrated tolerance)."""
    c = require_phase(cur, "chaos", source="current geo")
    b = require_phase(base, "chaos", source="baseline geo")
    partition = require_phase(c, "partition", source="current chaos")
    for field in ("converged_identical",):
        if not c.get(field):
            failures.append(f"chaos {field} is no longer asserted true")
    for field in ("recovered", "detection_marked_region_down"):
        if not partition.get(field):
            failures.append(f"chaos partition {field} is no longer asserted true")
    drift = [
        k
        for k in b
        if k not in ("goodput_rows_per_s",) and c.get(k) != b[k]
    ]
    if drift:
        for k in drift:
            failures.append(
                f"chaos {k} drifted: {c.get(k)} vs committed {b[k]} "
                f"(seeded + logical ticks — re-commit "
                f"BENCH_geo_replication.json if intentional)"
            )
    else:
        print(
            f"  ok: chaos deterministic ledger exact (rounds "
            f"{c['drain_rounds']}, retries {c['retried_batches']}, "
            f"timeouts {c['timeouts']}, corrupt {c['corrupt_frames']}, "
            f"amplification {c['retry_amplification_x']}x; partition dead@"
            f"{c['partition']['dead_at_round']} -> recovered)"
        )
    got = c["goodput_rows_per_s"]
    floor = int(b["goodput_rows_per_s"] * scale * (1.0 - tolerance))
    if got < floor:
        failures.append(
            f"chaos goodput dropped >{tolerance:.0%}: {got} rows/s vs {floor}"
        )
    else:
        print(f"  ok: chaos goodput {got} rows/s (calibrated floor {floor})")


def check_multi_home(
    cur: dict, base: dict, tolerance: float, failures: list[str]
) -> None:
    """Active-active multi-home gates (ISSUE 9).  EXACT: per-shard shipped
    wire bytes — each home's log carries only its owned range's slices
    (the echo-breaking publish filter), and the workload is seeded +
    fixed-shape, so any drift means the shard filter, the key hash, or
    the wire format changed and the artifact must be re-committed
    deliberately.  ABSOLUTE: every convergence boolean (steady-state,
    post-per-shard-failover, post-rejoin-rebalance) is re-asserted fresh.
    CALIBRATED: the forwarded-write fraction is a pure function of the
    shard coordinate hash (~(R-1)/R for R uniform ranges), gated within
    the same tolerance as the wall-clock numbers so a routing bug that
    stops (or starts over-) forwarding fails the gate without pinning the
    hash itself."""
    c = require_phase(cur, "multi_home", source="current geo")
    b = require_phase(base, "multi_home", source="baseline geo")
    got_bytes, want_bytes = c["per_shard_shipped_bytes"], b["per_shard_shipped_bytes"]
    if got_bytes != want_bytes:
        failures.append(
            f"multi-home per-shard shipped bytes drifted: {got_bytes} vs "
            f"committed {want_bytes} (re-commit BENCH_geo_replication.json "
            f"if intentional)"
        )
    else:
        print(
            f"  ok: multi-home per-shard shipped bytes exact "
            f"({sum(got_bytes.values())} B over {len(got_bytes)} shards)"
        )
    for field, sub in (
        ("online_identical", None),
        ("offline_identical", None),
        ("online_identical", "failover"),
        ("offline_identical", "failover"),
        ("online_identical", "rejoin_rebalance"),
        ("offline_identical", "rejoin_rebalance"),
    ):
        scope = (
            c
            if sub is None
            else require_phase(c, sub, source="current multi_home")
        )
        if not scope.get(field):
            where = f"{sub}." if sub else ""
            failures.append(
                f"multi-home {where}{field} is no longer asserted true"
            )
    got_f, want_f = c["forwarded_fraction"], b["forwarded_fraction"]
    if abs(got_f - want_f) > tolerance * want_f:
        failures.append(
            f"multi-home forwarded-write fraction drifted >{tolerance:.0%}: "
            f"{got_f} vs committed {want_f}"
        )
    else:
        print(
            f"  ok: multi-home forwarded fraction {got_f} "
            f"(committed {want_f}, converged in {c['converge_rounds']} "
            f"round(s), failover moved shards {c['failover']['shards_moved']})"
        )


def check_socket(cur: dict, base: dict, failures: list[str]) -> None:
    """Real-socket transport gates (ISSUE 8).  EXACT: the socket phase
    ships the same seeded 100k-row window as the throughput bench, so its
    wire-byte and frame counts are deterministic — and the serialized and
    pipelined runs must ship identical bytes (pipelining is a scheduling
    change, not a format change; the bench asserts that internally and
    the counts are re-gated here).  ABSOLUTE: both convergence booleans
    (online byte-identical / offline chunk-set-identical against the
    daemon's dump stream) are re-asserted fresh, no frame may be NACKed
    or timed out on the clean localhost link, and the pipelined drain
    must beat the serialized (window=1) drain outright — the emulated
    round-trip dominates both walls, so the ratio is a property of the
    window, not of machine speed."""
    c = require_phase(cur, "socket", source="current geo")
    b = require_phase(base, "socket", source="baseline geo")
    for field in ("socket_state_identical", "socket_offline_state_identical"):
        if not c.get(field):
            failures.append(f"socket {field} is no longer asserted true")
    for field in ("wire_frames", "shipped_bytes", "shipped_raw_bytes"):
        got, want = c[field], b[field]
        if got != want:
            failures.append(
                f"socket {field} drifted: {got} vs committed {want} "
                f"(re-commit BENCH_geo_replication.json if intentional)"
            )
        else:
            print(f"  ok: socket {field} {got} (exact match)")
    for mode in ("serialized", "pipelined"):
        m = require_phase(c, mode, source="current socket")
        if m["nacks"] or m["timeouts"]:
            failures.append(
                f"socket {mode} run was not clean: nacks="
                f"{m['nacks']} timeouts={m['timeouts']}"
            )
    speedup = c["pipeline_speedup_x"]
    if speedup <= 1.0:
        failures.append(
            f"pipelined drain no longer beats serialized: "
            f"{speedup}x (committed {b['pipeline_speedup_x']}x)"
        )
    else:
        print(
            f"  ok: socket pipeline speedup {speedup}x over window=1 "
            f"(committed {b['pipeline_speedup_x']}x)"
        )


def check_serving(
    cur: dict, base: dict, tolerance: float, scale: float, failures: list[str]
) -> None:
    """Serving-front gates (ISSUE 6).  Three classes:

    ABSOLUTE (machine-independent by construction): the closed-loop
    kernel-over-host per-lookup ratio must stay <= 2.0 while the mean
    coalesced dispatch stays >= 2048 keys — the tentpole acceptance
    criterion, re-checked on every run, not just when the baseline was
    committed.  Overload must still degrade AND shed, with no stale read
    over the configured bound.

    EXACT (seeded + round-driven, so any drift is a behavior change): the
    closed-loop cache hit rate per engine stack must not drop below the
    committed value.

    CALIBRATED (wall-clock): closed-loop lookups/s per stack within
    ``tolerance`` of the committed baseline after the loop-engine
    machine-speed rescale."""
    c = require_phase(cur, "closed_loop", source="current serving")
    b = require_phase(base, "closed_loop", source="baseline serving")
    base_overload = require_phase(base, "overload", source="baseline serving")
    ratio = c["kernel_over_host_x"]
    if ratio > 2.0:
        failures.append(f"serving kernel/host per-lookup ratio {ratio} > 2.0")
    else:
        print(f"  ok: serving kernel/host ratio {ratio}x (<= 2.0)")
    for stack in ("host", "kernel"):
        sc = require_phase(c, stack, source="current serving closed_loop")
        sb = require_phase(b, stack, source="baseline serving closed_loop")
        mean_co = sc["mean_coalesced_keys"]
        if mean_co < 2_048:
            failures.append(
                f"serving {stack} mean coalesced dispatch fell to {mean_co} "
                f"keys (< 2048: out of the micro-batched regime)"
            )
        got, want = sc["cache_hit_rate"], sb["cache_hit_rate"]
        if got < want:
            failures.append(
                f"serving {stack} cache hit rate dropped: {got} vs committed "
                f"{want} (deterministic workload — this is a behavior change)"
            )
        else:
            print(f"  ok: serving {stack} hit rate {got} (committed {want})")
        rate = sc["lookups_per_s"]
        floor = int(sb["lookups_per_s"] * scale * (1.0 - tolerance))
        if rate < floor:
            failures.append(
                f"serving {stack} closed-loop dropped >{tolerance:.0%}: "
                f"{rate} lookups/s vs calibrated floor {floor}"
            )
        else:
            print(f"  ok: serving {stack} {rate} lookups/s (floor {floor})")
        if sc["max_stale_age_ms"] > base_overload["staleness_bound_ms"]:
            failures.append(
                f"serving {stack} served a read staler than the bound: "
                f"{sc['max_stale_age_ms']} ms"
            )
    o = require_phase(cur, "overload", source="current serving")
    if not (o["degraded"] > 0 and o["shed"] > 0):
        failures.append(f"serving overload no longer degrades AND sheds: {o}")
    elif o["max_stale_age_ms"] > o["staleness_bound_ms"]:
        failures.append(
            f"serving overload stale read {o['max_stale_age_ms']} ms over "
            f"bound {o['staleness_bound_ms']} ms"
        )
    else:
        print(
            f"  ok: overload degraded {o['degraded']} / shed {o['shed']}, "
            f"max stale {o['max_stale_age_ms']} ms <= {o['staleness_bound_ms']}"
        )


def main() -> None:
    repo = Path(__file__).resolve().parent.parent
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--current",
        default=str(repo / "results" / "bench_fast.json"),
        help="fresh bench output (benchmarks/run.py --fast --only online_store)",
    )
    ap.add_argument(
        "--baseline",
        default=str(repo / "BENCH_online_store.json"),
        help="committed trajectory artifact to gate against",
    )
    ap.add_argument(
        "--geo-baseline",
        default=str(repo / "BENCH_geo_replication.json"),
        help="committed geo-replication artifact (pass '' to skip geo gates)",
    )
    ap.add_argument(
        "--serving-baseline",
        default=str(repo / "BENCH_serving.json"),
        help="committed serving-front artifact (pass '' to skip serving gates)",
    )
    ap.add_argument(
        "--tolerance",
        type=float,
        default=float(os.environ.get("BENCH_TOLERANCE", "0.30")),
        help="allowed fractional rows/s drop after calibration (default 0.30)",
    )
    args = ap.parse_args()

    cur = load_suite_result(Path(args.current), "online_store")
    base = load_suite_result(Path(args.baseline), "online_store")

    failures: list[str] = []
    print("bench-regression gate:")
    check_transfer_bytes(cur, base, failures)
    scale = check_merge_throughput(cur, base, args.tolerance, failures)
    if args.geo_baseline:
        geo_cur = load_suite_result(Path(args.current), "geo_replication")
        geo_base = load_suite_result(Path(args.geo_baseline), "geo_replication")
        check_geo_replication(geo_cur, geo_base, args.tolerance, scale, failures)
        check_chaos(geo_cur, geo_base, args.tolerance, scale, failures)
        check_socket(geo_cur, geo_base, failures)
        check_multi_home(geo_cur, geo_base, args.tolerance, failures)
    if args.serving_baseline:
        srv_cur = load_suite_result(Path(args.current), "serving")
        srv_base = load_suite_result(Path(args.serving_baseline), "serving")
        check_serving(srv_cur, srv_base, args.tolerance, scale, failures)
    if failures:
        print("\nREGRESSIONS DETECTED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        raise SystemExit(1)
    print("bench-regression gate: PASS")


if __name__ == "__main__":
    main()
