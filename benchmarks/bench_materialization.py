"""Benchmark: §4.3/§4.5 materialization pipeline + fault tolerance.

  * scheduled-incremental throughput: source rows/s through Algorithm 1
    (read window -> transform -> filter) + Algorithm 2 merges
  * backfill: wall time for an on-demand window, and the §3.1.1 invariant
    (suspended schedules resume; no overlapping jobs) under load
  * fault injection: convergence under failure probability p — retries to
    eventual consistency (§4.5.4), reporting retry overhead factor
  * Fig.5 record-semantics check at benchmark scale (offline keeps all,
    online keeps latest)
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.assets import Entity, Feature, FeatureSetSpec, MaterializationSettings
from repro.core.dsl import DslTransform, RollingAgg
from repro.core.featurestore import FeatureStore
from repro.data.sources import SyntheticEventSource

HOUR = 3_600_000


def _make(entities=2_000, rate=800, fail_p=0.0, seed=0) -> FeatureStore:
    fs = FeatureStore("bench-mat", interpret=True)
    src = SyntheticEventSource(
        "tx", seed=seed, num_entities=entities, events_per_bucket=rate
    )
    fs.register_source(src)
    fs.create_feature_set(
        FeatureSetSpec(
            name="act", version=1,
            entity=Entity("customer", ("entity_id",)),
            features=(Feature("s2", "float32"), Feature("c2", "float32")),
            source_name="tx",
            transform=DslTransform("entity_id", "ts", [
                RollingAgg("s2", "amount", 2 * HOUR, "sum"),
                RollingAgg("c2", "amount", 2 * HOUR, "count"),
            ]),
            timestamp_col="ts", source_lookback=2 * HOUR,
            materialization=MaterializationSettings(
                offline_enabled=True, online_enabled=True, schedule_interval=HOUR
            ),
        )
    )
    if fail_p:
        fs.faults.set_failure_rate(fail_p, seed=seed)
    return fs


def run(hours=16, fail_ps=(0.0, 0.15, 0.3)) -> dict:
    # -- throughput ------------------------------------------------------------
    fs = _make()
    t0 = time.perf_counter()
    stats = fs.tick(now=hours * HOUR)
    wall = time.perf_counter() - t0
    n_rows = len(fs.offline.read("act", 1))
    throughput = {
        "hours_materialized": hours,
        "jobs": stats,
        "feature_rows": n_rows,
        "rows_per_s": int(n_rows / max(wall, 1e-9)),
        "wall_s": round(wall, 3),
    }

    # -- backfill + scheduling invariant ------------------------------------------
    fs2 = _make(seed=1)
    fs2.tick(now=6 * HOUR)
    t0 = time.perf_counter()
    bstats = fs2.backfill("act", 1, start=0, end=3 * HOUR)
    t_backfill = time.perf_counter() - t0
    intervals = fs2.scheduler.materialized_intervals("act", 1)
    backfill = {
        "jobs": bstats,
        "wall_s": round(t_backfill, 3),
        "timeline_contiguous": intervals == [(0, 6 * HOUR)],
        "alerts": list(fs2.scheduler.alerts),
    }

    # -- fault-injected convergence (§4.5.4) ----------------------------------------
    fault_rows = []
    for p in fail_ps:
        fsf = _make(seed=2, fail_p=p)
        t0 = time.perf_counter()
        st = fsf.tick(now=8 * HOUR)
        repairs = 0
        while fsf.scheduler.materialized_intervals("act", 1) != [(0, 8 * HOUR)]:
            r = fsf.repair("act", 1)
            st = {k: st[k] + r[k] for k in st}
            repairs += 1
            if repairs > 20:
                break
        wall_f = time.perf_counter() - t0
        rep = fsf.check_consistency("act", 1)
        iv = fsf.scheduler.materialized_intervals("act", 1)
        fault_rows.append({
            "failure_p": p,
            "jobs": st,
            "eventually_consistent": bool(rep.consistent),
            "timeline_complete": iv == [(0, 8 * HOUR)],
            "repair_rounds": repairs,
            "alerts": len(fsf.scheduler.alerts),
            "retry_overhead_x": round(
                (st["succeeded"] + st["retried"]) / max(st["succeeded"], 1), 2
            ),
            "wall_s": round(wall_f, 3),
        })

    # -- Fig.5 semantics at scale -----------------------------------------------------
    hist = fs.offline.read("act", 1)
    per_id_offline = len(hist)
    uniq = len(np.unique(hist["__key__"]))
    fig5 = {
        "offline_records": per_id_offline,
        "distinct_ids": uniq,
        "offline_keeps_history": per_id_offline > uniq,  # many records per id
        "online_keeps_latest_only": bool(fs.check_consistency("act", 1).consistent),
    }

    return {
        "throughput": throughput,
        "backfill": backfill,
        "fault_tolerance": fault_rows,
        "fig5_semantics": fig5,
    }


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
