"""Benchmark: §4.3/§4.5 materialization pipeline + fault tolerance.

  * merge-engine throughput: rows/s through offline+online Algorithm-2
    merges at a 100k-row window — old-style sequential loop vs the
    vectorized merge engine (the tentpole comparison)
  * scheduled-incremental throughput: source rows/s through Algorithm 1
    (read window -> transform -> filter) + Algorithm 2 merges
  * backfill: wall time for an on-demand window, and the §3.1.1 invariant
    (suspended schedules resume; no overlapping jobs) under load
  * fault injection: convergence under failure probability p — retries to
    eventual consistency (§4.5.4), reporting retry overhead factor
  * Fig.5 record-semantics check at benchmark scale (offline keeps all,
    online keeps latest)
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.assets import Entity, Feature, FeatureSetSpec, MaterializationSettings
from repro.core.dsl import DslTransform, RollingAgg
from repro.core.featurestore import FeatureStore
from repro.core.offline_store import OfflineStore
from repro.core.online_store import OnlineStore
from repro.core.table import Table
from repro.data.sources import SyntheticEventSource

HOUR = 3_600_000


def _make(entities=2_000, rate=800, fail_p=0.0, seed=0) -> FeatureStore:
    fs = FeatureStore("bench-mat", interpret=True)
    src = SyntheticEventSource(
        "tx", seed=seed, num_entities=entities, events_per_bucket=rate
    )
    fs.register_source(src)
    fs.create_feature_set(
        FeatureSetSpec(
            name="act", version=1,
            entity=Entity("customer", ("entity_id",)),
            features=(Feature("s2", "float32"), Feature("c2", "float32")),
            source_name="tx",
            transform=DslTransform("entity_id", "ts", [
                RollingAgg("s2", "amount", 2 * HOUR, "sum"),
                RollingAgg("c2", "amount", 2 * HOUR, "count"),
            ]),
            timestamp_col="ts", source_lookback=2 * HOUR,
            materialization=MaterializationSettings(
                offline_enabled=True, online_enabled=True, schedule_interval=HOUR
            ),
        )
    )
    if fail_p:
        fs.faults.set_failure_rate(fail_p, seed=seed)
    return fs


def _merge_spec() -> FeatureSetSpec:
    from repro.core.dsl import UDFTransform

    return FeatureSetSpec(
        name="merge-bench", version=1,
        entity=Entity("customer", ("entity_id",)),
        features=(Feature("f0", "float32"), Feature("f1", "float32")),
        source_name="direct",
        transform=UDFTransform(lambda df, ctx: df, name="id"),
        timestamp_col="ts",
        materialization=MaterializationSettings(True, True),
    )


def _merge_frame(rng, n: int, t0: int) -> Table:
    return Table(
        {
            "entity_id": rng.integers(0, 20_000, n).astype(np.int64),
            "ts": (t0 + rng.integers(0, 10**6, n)).astype(np.int64),
            "f0": rng.random(n).astype(np.float32),
            "f1": rng.random(n).astype(np.float32),
        }
    )


class _SeedStores:
    """Faithful replica of the SEED (pre-merge-engine) write path, pinned
    here so the benchmark baseline never drifts as the real stores improve:
    offline = per-row ``set[tuple]`` dedup + ``concat_tables`` on EVERY
    merge (O(history)); online = per-row dict-probe Algorithm-2 loop.
    Storage detail (monolithic table / slot planes) matches the seed."""

    def __init__(self, spec, num_shards=4, num_partitions=16, capacity=256):
        from repro.core.keys import encode_keys
        from repro.core.offline_store import _record_schema
        from repro.core.table import concat_tables
        from repro.kernels.online_lookup.ops import partition_of, split_i64

        self._encode = encode_keys
        self._partition_of = partition_of
        self._split = split_i64
        self._concat = concat_tables
        self.spec = spec
        self.num_shards = num_shards
        self.num_partitions = num_partitions
        self.off_tables = [Table.empty(_record_schema(spec)) for _ in range(num_shards)]
        self.off_keys = [set() for _ in range(num_shards)]
        p, d = num_partitions, len(spec.features)
        self.keys_full = np.full((p, capacity), -1, np.int64)
        self.event_ts = np.zeros((p, capacity), np.int64)
        self.creation_ts = np.zeros((p, capacity), np.int64)
        self.values = np.zeros((p, capacity, d), np.float32)
        self.fill = np.zeros(p, np.int64)
        self.slot_of: dict = {}

    def merge(self, frame: Table, creation_ts: int) -> None:
        spec = self.spec
        ids = self._encode([frame[c] for c in spec.index_columns])
        event_ts = frame[spec.timestamp_col].astype(np.int64)
        # -- offline branch (seed: set[tuple] + concat per merge)
        shard_of = self._partition_of(ids, self.num_shards)
        for s in range(self.num_shards):
            mask = shard_of == s
            if not mask.any():
                continue
            sub_ids, sub_ev = ids[mask], event_ts[mask]
            keep = np.zeros(mask.sum(), bool)
            for i, (k, ev) in enumerate(zip(sub_ids, sub_ev)):
                full = (int(k), int(ev), creation_ts)
                if full not in self.off_keys[s]:
                    self.off_keys[s].add(full)
                    keep[i] = True
            if not keep.any():
                continue
            sub = frame.filter(mask).filter(keep)
            cols = {"__key__": sub_ids[keep]}
            for c in spec.index_columns:
                cols[c] = sub[c].astype(np.int64)
            cols["event_ts"] = sub[spec.timestamp_col].astype(np.int64)
            cols["creation_ts"] = np.full(len(sub), creation_ts, np.int64)
            for f in spec.features:
                cols[f.name] = sub[f.name].astype(f.np_dtype())
            self.off_tables[s] = self._concat([self.off_tables[s], Table(cols)])
        # -- online branch (seed: per-row dict probe)
        feats = np.stack(
            [frame[f.name].astype(np.float32) for f in spec.features], axis=1
        )
        parts = self._partition_of(ids, self.num_partitions)
        for i in range(len(ids)):
            key_i, ev_i, p = int(ids[i]), int(event_ts[i]), int(parts[i])
            existing = self.slot_of.get(key_i)
            if existing is None:
                if self.fill[p] >= self.keys_full.shape[1]:
                    grow = lambda a, v: np.concatenate(
                        [a, np.full_like(a, v)], axis=1
                    )
                    self.keys_full = grow(self.keys_full, -1)
                    self.event_ts = grow(self.event_ts, 0)
                    self.creation_ts = grow(self.creation_ts, 0)
                    self.values = np.concatenate(
                        [self.values, np.zeros_like(self.values)], axis=1
                    )
                slot = int(self.fill[p])
                self.keys_full[p, slot] = key_i
                self.event_ts[p, slot] = ev_i
                self.creation_ts[p, slot] = creation_ts
                self.values[p, slot] = feats[i]
                self.slot_of[key_i] = (p, slot)
                self.fill[p] += 1
            else:
                pp, slot = existing
                old = (int(self.event_ts[pp, slot]), int(self.creation_ts[pp, slot]))
                if (ev_i, creation_ts) > old:
                    self.event_ts[pp, slot] = ev_i
                    self.creation_ts[pp, slot] = creation_ts
                    self.values[pp, slot] = feats[i]


class _Pr1KernelStore(OnlineStore):
    """Faithful replica of PR 1's kernel engine, pinned here so the
    device-resident trajectory baseline can't drift: identical host planning
    (plan + sorted-index slot resolution), but every merge streams the FULL
    table through the Pallas scan kernel with a host round-trip — re-upload
    all (P, C) planes, pull them all back — instead of the resident
    donated-buffer scatter.  Measured in the same run as the real engines so
    the speedup ratio is machine-condition-independent."""

    def _merge_vector(
        self, key, ids, event_ts, frame, fnames, creation_ts, *, use_kernel=True
    ):
        from repro.core.merge_engine import INT64_MIN, plan_online_batch
        from repro.kernels.online_lookup import ops as lookup_ops
        from repro.kernels.online_merge import ops as merge_ops

        t = self._tables[key]
        t.slot_cache = None

        def resolve(uids):
            part_e, slot_e, found = self._index_find(t, uids)
            resolve.parts, resolve.slots = part_e, slot_e
            return t.event_ts[part_e, slot_e], t.creation_ts[part_e, slot_e], found

        plan = plan_online_batch(ids, event_ts, creation_ts, resolve)
        part_e, slot_e = resolve.parts, resolve.slots
        found = ~plan.is_new
        wfeats = np.stack(
            [np.asarray(frame[n], np.float32)[plan.winner_row] for n in fnames],
            axis=1,
        )
        self.inserts += plan.inserts
        self.overrides += plan.overrides
        self.noops += plan.noops
        new = plan.is_new
        if new.any():
            ins_ids = plan.uids[new]
            arrival = np.argsort(plan.first_row[new], kind="stable")
            ins_ids_o = ins_ids[arrival]
            parts_o = lookup_ops.partition_of(ins_ids_o, self.num_partitions)
            counts = np.bincount(parts_o, minlength=self.num_partitions)
            while (t.fill + counts).max() > t.keys_lo.shape[1]:
                self._grow(key)
            po = np.argsort(parts_o, kind="stable")
            parts_sorted = parts_o[po]
            rank = np.arange(len(po)) - np.searchsorted(parts_sorted, parts_sorted)
            slots_o = np.empty(len(po), np.int64)
            slots_o[po] = t.fill[parts_sorted] + rank
            t.fill += counts
            lo, hi = lookup_ops.split_i64(ins_ids_o)
            t.keys_lo[parts_o, slots_o] = lo
            t.keys_hi[parts_o, slots_o] = hi
            t.keys_full[parts_o, slots_o] = ins_ids_o
            self._index_insert(t, ins_ids_o, parts_o, slots_o)
            t.event_ts[parts_o, slots_o] = INT64_MIN
            t.creation_ts[parts_o, slots_o] = INT64_MIN
        t.event_ts, t.creation_ts, t.values = merge_ops.route_and_merge(
            t.keys_lo, t.keys_hi, t.event_ts, t.creation_ts, t.values,
            plan.uids, plan.winner_ev, wfeats,
            creation_ts, interpret=self.interpret,
        )
        return {
            "engine": "kernel_pr1", "inserts": plan.inserts,
            "overrides": plan.overrides, "noops": plan.noops,
            "touched_parts": np.empty(0, np.int64),
            "touched_slots": np.empty(0, np.int64),
        }


def bench_merge_engines(
    window_rows: int = 100_000, batches: int = 1, trials: int = 5
) -> dict:
    """Rows/s through offline+online Algorithm-2 merges of a
    ``window_rows``-row window (after a same-size seeded history), per write
    path.  ``batches=1`` mirrors the Materializer: one job window produces
    ONE frame and each store gets one merge call.  ``seed`` is a faithful
    replica of the pre-engine implementation (the acceptance baseline,
    pinned so it can't drift); ``loop`` is the retained per-row reference
    inside the NEW storage layout; ``vector`` is the merge engine.  Median
    of ``trials`` each — medians beat best-of here because a lucky quiet
    trial flatters the noise-sensitive python-loop baselines far more than
    the vectorized path, skewing the ratio."""
    spec = _merge_spec()
    out: dict = {"window_rows": window_rows, "batches": batches}
    per_batch = window_rows // batches

    def _drive(make, merge):
        walls = []
        for _ in range(trials):
            rng = np.random.default_rng(1)
            state = make()
            merge(state, _merge_frame(rng, window_rows, 0), 10**7)
            frames = [
                _merge_frame(rng, per_batch, 10**6 * (i + 2))
                for i in range(batches)
            ]
            t0 = time.perf_counter()
            for i, f in enumerate(frames):
                merge(state, f, 10**8 + i)
            walls.append(time.perf_counter() - t0)
        med = float(np.median(walls))
        return {"rows_per_s": int(window_rows / med), "wall_s": round(med, 4)}

    out["seed"] = _drive(
        lambda: _SeedStores(spec), lambda st, f, cr: st.merge(f, cr)
    )
    for engine, make_online in (
        ("loop", OnlineStore),
        ("vector", OnlineStore),
        ("kernel", OnlineStore),
        ("kernel_pr1", _Pr1KernelStore),
    ):
        store_engine = "kernel" if engine == "kernel_pr1" else engine
        out[engine] = _drive(
            lambda: (
                OfflineStore(num_shards=4, merge_engine=store_engine),
                make_online(merge_engine=store_engine),
            ),
            lambda st, f, cr: (st[0].merge(spec, f, cr), st[1].merge(spec, f, cr)),
        )
    out["speedup_vs_seed_x"] = round(
        out["vector"]["rows_per_s"] / max(out["seed"]["rows_per_s"], 1), 1
    )
    out["speedup_vs_loop_x"] = round(
        out["vector"]["rows_per_s"] / max(out["loop"]["rows_per_s"], 1), 1
    )
    # device-resident trajectory (ISSUE 2 acceptance): PR 1's kernel path
    # re-uploaded every (P, C) plane per merge and pulled them all back —
    # the resident engine must beat that same-run replica by >= 3x
    out["kernel"]["speedup_vs_pr1_kernel_x"] = round(
        out["kernel"]["rows_per_s"] / max(out["kernel_pr1"]["rows_per_s"], 1), 1
    )
    return out


def run(hours=16, fail_ps=(0.0, 0.15, 0.3), merge_window=100_000) -> dict:
    # -- merge-engine comparison (tentpole: old-style loop vs engine) ----------
    merge_engines = bench_merge_engines(window_rows=merge_window)

    # -- throughput ------------------------------------------------------------
    fs = _make()
    t0 = time.perf_counter()
    stats = fs.tick(now=hours * HOUR)
    wall = time.perf_counter() - t0
    n_rows = len(fs.offline.read("act", 1))
    throughput = {
        "hours_materialized": hours,
        "jobs": stats,
        "feature_rows": n_rows,
        "rows_per_s": int(n_rows / max(wall, 1e-9)),
        "wall_s": round(wall, 3),
    }

    # -- backfill + scheduling invariant ------------------------------------------
    fs2 = _make(seed=1)
    fs2.tick(now=6 * HOUR)
    t0 = time.perf_counter()
    bstats = fs2.backfill("act", 1, start=0, end=3 * HOUR)
    t_backfill = time.perf_counter() - t0
    intervals = fs2.scheduler.materialized_intervals("act", 1)
    backfill = {
        "jobs": bstats,
        "wall_s": round(t_backfill, 3),
        "timeline_contiguous": intervals == [(0, 6 * HOUR)],
        "alerts": list(fs2.scheduler.alerts),
    }

    # -- fault-injected convergence (§4.5.4) ----------------------------------------
    fault_rows = []
    for p in fail_ps:
        fsf = _make(seed=2, fail_p=p)
        t0 = time.perf_counter()
        st = fsf.tick(now=8 * HOUR)
        repairs = 0
        while fsf.scheduler.materialized_intervals("act", 1) != [(0, 8 * HOUR)]:
            r = fsf.repair("act", 1)
            st = {k: st[k] + r[k] for k in st}
            repairs += 1
            if repairs > 20:
                break
        wall_f = time.perf_counter() - t0
        rep = fsf.check_consistency("act", 1)
        iv = fsf.scheduler.materialized_intervals("act", 1)
        fault_rows.append(
            {
                "failure_p": p,
                "jobs": st,
                "eventually_consistent": bool(rep.consistent),
                "timeline_complete": iv == [(0, 8 * HOUR)],
                "repair_rounds": repairs,
                "alerts": len(fsf.scheduler.alerts),
                "retry_overhead_x": round(
                    (st["succeeded"] + st["retried"]) / max(st["succeeded"], 1), 2
                ),
                "wall_s": round(wall_f, 3),
            }
        )

    # -- Fig.5 semantics at scale -----------------------------------------------------
    hist = fs.offline.read("act", 1)
    per_id_offline = len(hist)
    uniq = len(np.unique(hist["__key__"]))
    fig5 = {
        "offline_records": per_id_offline,
        "distinct_ids": uniq,
        "offline_keeps_history": per_id_offline > uniq,  # many records per id
        "online_keeps_latest_only": bool(fs.check_consistency("act", 1).consistent),
    }

    return {
        "merge_engines": merge_engines,
        "throughput": throughput,
        "backfill": backfill,
        "fault_tolerance": fault_rows,
        "fig5_semantics": fig5,
    }


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
