"""Benchmark harness: ``PYTHONPATH=src python -m benchmarks.run``.

One module per claim in the paper (§ refs in each module's docstring):

  rolling_dsl      §3.1.6  DSL-optimized aggregation vs black-box UDF
  pit_retrieval    §4.4    point-in-time offline retrieval throughput
  online_store     §2.1/§4.5  online GET latency + Algorithm-2 merge + staleness
  serving          §2.1/§3.1.4  serving front: coalesced GET amortization,
                   zipfian closed-loop latency + hit rate, overload shedding
  materialization  §4.3/§4.5.4  pipeline throughput, backfill, fault injection
  geo              §4.1.2  cross-region access vs geo-replication + stragglers
  geo_replication  §4.1.2  the replication data plane measured: ship/apply
                   throughput, local-read latency, failover replay
  roofline         (g)     §Roofline table from the dry-run artifacts

Writes results/benchmarks.json; ``--only <name>`` runs a subset; ``--fast``
shrinks workloads (CI).
"""

from __future__ import annotations

import argparse
import json
import time
import traceback
from pathlib import Path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma-separated subset")
    ap.add_argument("--fast", action="store_true", help="small workloads (CI)")
    ap.add_argument("--out", default="results/benchmarks.json")
    args = ap.parse_args()

    from benchmarks import (  # noqa: PLC0415 — import after arg parsing
        bench_geo,
        bench_geo_replication,
        bench_materialization,
        bench_online_store,
        bench_pit_retrieval,
        bench_rolling_dsl,
        bench_serving,
        roofline_summary,
    )

    suites = {
        "rolling_dsl": lambda: bench_rolling_dsl.run(
            sizes=(2_000, 10_000) if args.fast else (2_000, 10_000, 50_000)
        ),
        "pit_retrieval": lambda: bench_pit_retrieval.run(
            spine_sizes=(1_000,) if args.fast else (1_000, 10_000)
        ),
        "online_store": lambda: bench_online_store.run(
            entity_counts=(1_000,) if args.fast else (1_000, 10_000)
        ),
        # fixed-shape even under --fast: the serving gates (hit rate,
        # coalesce sizes, overload counts) are exact, not calibrated
        "serving": lambda: bench_serving.run(fast=args.fast),
        "materialization": lambda: bench_materialization.run(
            hours=6 if args.fast else 16,
            merge_window=20_000 if args.fast else 100_000,
        ),
        "geo": bench_geo.run,
        "geo_replication": lambda: bench_geo_replication.run(fast=args.fast),
        "roofline": lambda: roofline_summary.summarize(),
    }
    only = {s for s in args.only.split(",") if s}
    results: dict = {}
    for name, fn in suites.items():
        if only and name not in only:
            continue
        print(f"=== bench: {name} ===", flush=True)
        t0 = time.time()
        try:
            results[name] = {"ok": True, "wall_s": None, "result": fn()}
            results[name]["wall_s"] = round(time.time() - t0, 2)
            print(json.dumps(results[name]["result"], indent=1, default=str)[:2000])
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            results[name] = {"ok": False, "error": f"{type(e).__name__}: {e}"}

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(results, indent=1, default=str))
    print(f"\nwrote {out}")

    # Standalone perf-trajectory artifacts, tracked PR-over-PR at the repo
    # root.  --fast runs use different workloads, so they must not overwrite
    # the tracked full-size numbers:
    #   BENCH_materialization.json — merge-path throughput trajectory
    #   BENCH_online_store.json    — serving-path latency (both GET paths) +
    #                                the resident-cycle transfer profile (the
    #                                O(batch) guarantee of the device-resident
    #                                online store)
    #   BENCH_serving.json         — serving-front trajectory: coalesced GET
    #                                amortization, closed-loop latency + cache
    #                                hit rate, overload degrade/shed counts
    def write_artifact(suite: str, filename: str, keys: tuple[str, ...]) -> None:
        res = results.get(suite)
        if not (res and res.get("ok")) or args.fast:
            return
        artifact = Path(__file__).resolve().parent.parent / filename
        artifact.write_text(
            json.dumps(
                {k: res["result"].get(k) for k in keys}, indent=1, default=str
            )
        )
        print(f"wrote {artifact}")

    write_artifact(
        "materialization", "BENCH_materialization.json",
        ("merge_engines", "throughput"),
    )
    write_artifact(
        "online_store", "BENCH_online_store.json",
        ("lookup_table", "merge_engines", "resident_cycle"),
    )
    write_artifact(
        "geo_replication", "BENCH_geo_replication.json",
        ("throughput", "read_latency", "failover", "chaos"),
    )
    write_artifact(
        "serving", "BENCH_serving.json",
        ("coalesced_lookup", "closed_loop", "overload"),
    )

    failed = [n for n, r in results.items() if not r.get("ok")]
    if failed:
        raise SystemExit(f"benchmark failures: {failed}")


if __name__ == "__main__":
    main()
