"""Benchmark: the §2.1/§3.1.4 online serving front under closed-loop load.

Three phases, written to ``BENCH_serving.json`` and gated by
``check_regression.py``:

  * COALESCED LOOKUP (raw amortization): many callers' point GETs coalesced
    into one store dispatch, cache OFF — per-lookup cost vs coalesce size on
    both engines.  This is the honest raw curve: micro-batching amortizes
    the per-dispatch overhead, but under Pallas INTERPRET mode the kernel's
    per-element compare-match cost is real (it is emulated elementwise), so
    the raw kernel path stays a constant factor behind the host path at any
    batch size here; on a real TPU the compare-match is one vector op per
    slot block and the crossover lands where dispatch overhead amortizes —
    i.e. exactly the ≥2k-coalesced regime this bench measures.
  * CLOSED LOOP (the acceptance number): zipfian keys, bursty arrivals,
    mixed read/write against a live ``Materializer`` tick cadence, through
    the FULL front (dedup + hot-key cache + one coalesced dispatch per
    round) on both engine stacks.  Per-lookup latency is end-to-end wall
    time over submitted keys; the gate asserts kernel-stack ≤ 2x host-stack
    while the mean dispatch still coalesces ≥ 2048 keys.  EVERYTHING the
    exact gates read (hit rate, coalesce sizes, shed/degraded counts) is
    driven by seeded RNG, round structure, and the logical data clock —
    wall time only feeds the latency numbers, so hit rate reproduces
    bit-for-bit across machines and ``--fast`` runs the same shape.
  * OVERLOAD: queue budget forced to zero so every request faces the
    degrade-or-shed decision: requests inside the staleness bound serve
    stale from cache (age recorded), requests beyond it or uncached shed.
    The staleness-bound assertion (stale reads never exceed the configured
    bound) runs IN the bench and fails it outright — deadline-driven
    admission (projected-wait vs budget) is covered by unit tests where
    clocks are injectable.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.assets import Entity, Feature, FeatureSetSpec, MaterializationSettings
from repro.core.dsl import DslTransform, RollingAgg, UDFTransform
from repro.core.featurestore import FeatureStore
from repro.core.online_store import OnlineStore
from repro.core.serving import PENDING, ServingConfig, ServingFront
from repro.core.table import Table
from repro.data.sources import SyntheticEventSource

HOUR = 3_600_000

# closed-loop shape — FIXED (no --fast variant): the hit-rate and coalesce
# gates are exact, so CI and the committed baseline must run one workload.
# The cache holds a quarter of the key space: the zipfian head stays resident
# (CLOCK ref bits) while the tail misses keep every round's coalesced
# dispatch comfortably in the >= 2048-key regime the acceptance gate names.
N_ENTITIES = 16_384
CALLER_KEYS = 512
BURST = (4, 8, 32, 16, 8, 24, 4, 32, 12, 28)  # callers per round (bursty)
ROUNDS = 40
TICK_EVERY = 8  # rounds between materializer ticks (the write mix)
ZIPF_S = 1.0
CACHE_CAPACITY = 4_096
STALENESS_BOUND_MS = 2_000


def _spec(n_feats: int = 2, ttl=None) -> FeatureSetSpec:
    return FeatureSetSpec(
        name="serve", version=1,
        entity=Entity("customer", ("entity_id",)),
        features=tuple(Feature(f"f{i}", "float32") for i in range(n_feats)),
        source_name="direct",
        transform=UDFTransform(lambda df, ctx: df, name="id"),
        timestamp_col="ts",
        materialization=MaterializationSettings(True, True, online_ttl=ttl),
    )


def _frame(rng, n: int, id_hi: int, ev_hi: int, n_feats: int = 2) -> Table:
    cols = {
        "entity_id": rng.integers(0, id_hi, n).astype(np.int64),
        "ts": rng.integers(0, ev_hi, n).astype(np.int64),
    }
    for i in range(n_feats):
        cols[f"f{i}"] = rng.random(n).astype(np.float32)
    return Table(cols)


def _zipf_cdf(n: int, s: float) -> np.ndarray:
    w = 1.0 / np.arange(1, n + 1, dtype=np.float64) ** s
    return np.cumsum(w) / w.sum()


def _zipf_draw(rng, cdf: np.ndarray, size: int) -> np.ndarray:
    return np.searchsorted(cdf, rng.random(size)).astype(np.int64)


# -- phase 1: raw coalescing amortization -------------------------------------


def bench_coalesced_lookup(
    sizes=(256, 2_048, 16_384), reps: int = 3
) -> list[dict]:
    """Cache-off front: N callers coalesce into one dispatch per engine.
    Reports per-lookup µs vs coalesce size — the raw amortization curve the
    tentpole claims, without the cache's help."""
    spec = _spec()
    store = OnlineStore(num_partitions=16, merge_engine="kernel")
    rng = np.random.default_rng(0)
    store.merge(spec, _frame(rng, 3 * N_ENTITIES, N_ENTITIES, 100), 1_000)
    front = ServingFront(store, config=ServingConfig(cache_capacity=0))
    out = []
    for total in sizes:
        n_callers = 16
        per = total // n_callers
        row = {"coalesced_keys": total, "callers": n_callers}
        for engine in ("host", "kernel"):
            times = []
            for rep in range(reps + 1):  # rep 0 = warmup (jit traces)
                r = np.random.default_rng(100 + rep)
                t0 = time.perf_counter()
                tickets = [
                    front.submit(
                        "serve", 1,
                        ids=r.integers(0, N_ENTITIES, per), now=1_050,
                    )
                    for _ in range(n_callers)
                ]
                front.flush("serve", 1, engine=engine, now=1_050)
                if rep:
                    times.append(time.perf_counter() - t0)
                assert all(t.status == "done" for t in tickets)
            row[engine] = {
                "per_lookup_us": round(float(np.mean(times)) / total * 1e6, 3),
                "dispatch_ms": round(float(np.mean(times)) * 1e3, 3),
            }
        row["kernel_over_host_x"] = round(
            row["kernel"]["per_lookup_us"] / row["host"]["per_lookup_us"], 2
        )
        out.append(row)
    return out


# -- phase 2: closed-loop traffic through the full front ----------------------


def _live_fs(merge_engine: str) -> FeatureStore:
    fs = FeatureStore(
        "bench-serving",
        merge_engine=merge_engine,
        serving=ServingConfig(
            cache_capacity=CACHE_CAPACITY,
            max_batch_keys=1 << 20,  # flushes are round-driven, not size-driven
            staleness_bound_ms=STALENESS_BOUND_MS,
        ),
    )
    fs.register_source(
        SyntheticEventSource(
            "tx", seed=7, num_entities=N_ENTITIES, events_per_bucket=2_500
        )
    )
    fs.create_feature_set(
        FeatureSetSpec(
            name="act", version=1,
            entity=Entity("customer", ("entity_id",)),
            features=(Feature("s2", "float32"),),
            source_name="tx",
            transform=DslTransform(
                "entity_id", "ts", [RollingAgg("s2", "amount", 2 * HOUR, "sum")]
            ),
            timestamp_col="ts", source_lookback=2 * HOUR,
            materialization=MaterializationSettings(
                offline_enabled=True, online_enabled=True,
                schedule_interval=HOUR,
            ),
        )
    )
    # pre-insert the whole entity space so table capacity is FINAL before the
    # measured loop: a mid-loop capacity grow would change every resident
    # plane's shape and recompile every jitted kernel bucket, billing ~100 ms
    # compile spikes to whichever stack's round the tick landed in
    spec = fs.registry.get_feature_set("act", 1)
    fs.online.merge(
        spec,
        Table(
            {
                "entity_id": np.arange(N_ENTITIES, dtype=np.int64),
                "ts": np.zeros(N_ENTITIES, np.int64),
                "s2": np.zeros(N_ENTITIES, np.float32),
            }
        ),
        1,
    )
    fs.tick(now=4 * HOUR)  # initial materialization through the live pipeline
    return fs


def _run_closed_loop(engine: str) -> dict:
    """One engine stack (kernel: kernel merges + kernel GETs; host: vector
    merges + host GETs — mixing stacks would thrash table-sized mirror syncs
    per switch, which is an anti-pattern the store docs call out)."""
    fs = _live_fs("kernel" if engine == "kernel" else "vector")
    front = fs.serving
    cdf = _zipf_cdf(N_ENTITIES, ZIPF_S)
    rng = np.random.default_rng(11)
    hour = 4
    read_wall = 0.0
    total_keys = 0
    dispatch_sizes = []
    # shape warmup (off the books): dispatch sizes jitter round-to-round, so
    # pre-trace every pow2 bucket the loop's store calls can land in — jit
    # compiles must not be billed to (only) the kernel stack's wall clock
    wrng = np.random.default_rng(999)
    for warm_b in (128, 256, 512, 1_024, 2_048, 4_096, 8_192):
        fs.online.lookup_encoded(
            "act", 1,
            wrng.integers(0, N_ENTITIES, warm_b).astype(np.int64),
            now=fs.clock(), use_kernel=(engine == "kernel"),
        )
    # warmup round (off the books): first-touch cache fill
    for _ in range(8):
        front.submit("act", 1, ids=_zipf_draw(rng, cdf, CALLER_KEYS))
    front.flush("act", 1, engine=engine)
    # the warmup dispatch absorbs the remaining jit compiles; drop its stage
    # samples so the reported p50/p99 describe only the measured rounds
    for st in ("queue_wait", "assembly", "kernel", "decode", "request"):
        fs.monitor.system.histograms.pop(f"serving/{st}_us", None)

    base_hits = front.counters["cache_hits"]
    base_misses = front.counters["cache_misses"]
    for rnd in range(ROUNDS):
        if rnd and rnd % TICK_EVERY == 0:
            hour += 1
            fs.tick(now=hour * HOUR)  # live writes -> cache invalidations
        before = front.counters["coalesced_keys"]
        callers = BURST[rnd % len(BURST)]
        t0 = time.perf_counter()
        tickets = [
            front.submit("act", 1, ids=_zipf_draw(rng, cdf, CALLER_KEYS))
            for _ in range(callers)
        ]
        front.flush("act", 1, engine=engine)
        read_wall += time.perf_counter() - t0
        assert all(t.status == "done" for t in tickets)
        total_keys += callers * CALLER_KEYS
        dispatched = front.counters["coalesced_keys"] - before
        if dispatched:
            dispatch_sizes.append(int(dispatched))

    s = front.stats()
    hits = s["cache_hits"] - base_hits
    misses = s["cache_misses"] - base_misses
    snap = fs.monitor.system.snapshot()
    stages = {
        st: {
            k: round(snap["histograms"][f"serving/{st}_us"][k], 1)
            for k in ("p50", "p99")
        }
        for st in ("queue_wait", "assembly", "kernel", "decode", "request")
    }
    assert s["max_stale_age_ms"] <= STALENESS_BOUND_MS  # the staleness SLA
    return {
        "engine": engine,
        "rounds": ROUNDS,
        "submitted_keys": total_keys,
        "per_lookup_us": round(read_wall / total_keys * 1e6, 3),
        "lookups_per_s": int(total_keys / read_wall),
        "cache_hit_rate": round(hits / (hits + misses), 6),
        "dispatches": len(dispatch_sizes),
        "mean_coalesced_keys": int(np.mean(dispatch_sizes)),
        "max_coalesced_keys": int(np.max(dispatch_sizes)),
        "unique_keys_dispatched": int(s["unique_keys"]),
        "store_keys_dispatched": int(s["store_keys"]),
        "max_stale_age_ms": s["max_stale_age_ms"],
        "stages_us": stages,
    }


def bench_closed_loop() -> dict:
    host = _run_closed_loop("host")
    kernel = _run_closed_loop("kernel")
    ratio = round(kernel["per_lookup_us"] / host["per_lookup_us"], 3)
    # the acceptance criterion, asserted in-bench: with >= 2048 coalesced
    # in-flight keys per dispatch, the micro-batched kernel path serves
    # within 2x of the host path per submitted lookup
    assert kernel["mean_coalesced_keys"] >= 2_048, kernel
    assert ratio <= 2.0, (ratio, kernel, host)
    # determinism cross-check: both stacks saw the same seeded key stream,
    # so their cache economics must agree exactly
    assert kernel["cache_hit_rate"] == host["cache_hit_rate"]
    return {"host": host, "kernel": kernel, "kernel_over_host_x": ratio}


# -- phase 3: overload — degrade inside the bound, shed beyond it -------------


def bench_overload() -> dict:
    spec = _spec(ttl=None)
    store = OnlineStore(num_partitions=8, merge_engine="vector")
    rng = np.random.default_rng(3)
    store.merge(spec, _frame(rng, 2_048, 512, 100), 1_000)
    front = ServingFront(
        store,
        config=ServingConfig(
            cache_capacity=1_024, staleness_bound_ms=STALENESS_BOUND_MS
        ),
    )
    all_ids = np.arange(512, dtype=np.int64)
    front.get("serve", 1, ids=all_ids, now=1_050, engine="host")  # warm cache
    store.merge(spec, _frame(rng, 2_048, 512, 200), 5_000)  # supersede all
    front.config.max_queue_keys = 0  # overload: nothing may queue

    stale_ages = []
    degraded = shed = 0
    for now, lo, hi in (
        (5_500, 0, 256),  # age  500 <= bound: degraded serves
        (6_800, 128, 384),  # age 1800 <= bound: degraded serves
        (7_500, 0, 256),  # age 2500  > bound: shed
        (6_000, 512, 768),  # never written, nothing cached: shed
    ):
        t = front.submit(
            "serve", 1, ids=np.arange(lo, hi, dtype=np.int64), now=now
        )
        assert t.status != PENDING
        if t.status == "done":
            degraded += 1
            stale_ages.append(t.stale_age_ms)
        else:
            shed += 1
    assert degraded == 2 and shed == 2, (degraded, shed)
    assert front.max_stale_age_ms <= STALENESS_BOUND_MS  # never over the bound
    return {
        "staleness_bound_ms": STALENESS_BOUND_MS,
        "degraded": degraded,
        "shed": shed,
        "stale_ages_ms": stale_ages,
        "max_stale_age_ms": front.max_stale_age_ms,
        "stale_reads_within_bound": True,  # asserted above
    }


def run(fast: bool = False) -> dict:
    # the exact gates need one fixed shape; ``fast`` only trims the raw
    # amortization sweep's repetitions, never the gated closed-loop phase
    return {
        "coalesced_lookup": bench_coalesced_lookup(reps=1 if fast else 3),
        "closed_loop": bench_closed_loop(),
        "overload": bench_overload(),
    }


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
