"""Benchmark: §4.4 point-in-time retrieval (offline training-frame builds).

Measures get_offline_features throughput (spine rows/s) as table/spine sizes
grow, on the XLA as-of path vs the naive per-row python join a hand-rolled
implementation would do (the paper's "complex and error prone" remark —
also slow).  The Pallas counting-search kernel is validated in tests; on CPU
it runs interpret-mode so its wall time is not meaningful — throughput here
is the XLA path that a kernel-less mesh would run.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.assets import Entity, Feature, FeatureSetSpec, MaterializationSettings
from repro.core.dsl import DslTransform, RollingAgg
from repro.core.featurestore import FeatureStore
from repro.core.offline_store import EVENT_TS
from repro.core.table import Table
from repro.data.sources import SyntheticEventSource

HOUR = 3_600_000


def _store(hours: int, entities: int) -> FeatureStore:
    fs = FeatureStore("bench", interpret=True)
    src = SyntheticEventSource(
        "tx", num_entities=entities, events_per_bucket=400
    )
    fs.register_source(src)
    fs.create_feature_set(
        FeatureSetSpec(
            name="act", version=1,
            entity=Entity("customer", ("entity_id",)),
            features=(Feature("s2", "float32"), Feature("m6", "float32")),
            source_name="tx",
            transform=DslTransform("entity_id", "ts", [
                RollingAgg("s2", "amount", 2 * HOUR, "sum"),
                RollingAgg("m6", "amount", 6 * HOUR, "mean"),
            ]),
            timestamp_col="ts", source_lookback=6 * HOUR,
            materialization=MaterializationSettings(
                offline_enabled=True, online_enabled=True,
                schedule_interval=HOUR,
            ),
        )
    )
    fs.tick(now=hours * HOUR)
    return fs


def _naive_pit(history: Table, spine: Table, feat_cols) -> np.ndarray:
    """Per-spine-row python binary-search join (the hand-rolled baseline)."""
    out = np.zeros((len(spine), len(feat_cols)), np.float32)
    ent = history["entity_id"]
    ts = history[EVENT_TS]
    for i in range(len(spine)):
        m = (ent == spine["entity_id"][i]) & (ts <= spine["ts"][i])
        idx = np.nonzero(m)[0]
        if len(idx):
            r = idx[np.argmax(ts[idx])]
            for j, c in enumerate(feat_cols):
                out[i, j] = history[c][r]
    return out


def run(spine_sizes=(1_000, 10_000), hours=24, entities=500) -> dict:
    fs = _store(hours, entities)
    hist = fs.offline.read("act", 1)
    rows = []
    rng = np.random.default_rng(0)
    for n in spine_sizes:
        spine = Table(
            {
                "entity_id": rng.integers(0, entities, n).astype(np.int64),
                "ts": rng.integers(2 * HOUR, hours * HOUR, n).astype(np.int64),
            }
        )
        t0 = time.perf_counter()
        frame = fs.get_offline_features(spine, [("act", 1)], use_kernel=False)
        t_sys = time.perf_counter() - t0
        t0 = time.perf_counter()
        frame = fs.get_offline_features(spine, [("act", 1)], use_kernel=False)
        t_sys_warm = time.perf_counter() - t0

        t_naive = None
        if n <= 1_000:  # naive is O(spine*history); cap it
            t0 = time.perf_counter()
            naive = _naive_pit(hist, spine, ["s2", "m6"])
            t_naive = time.perf_counter() - t0
            got = np.stack([frame["act:v1:s2"], frame["act:v1:m6"]], axis=1)
            found = frame["act:v1:__found__"].astype(bool)
            np.testing.assert_allclose(got[found], naive[found], rtol=1e-4, atol=1e-3)

        rows.append(
            {
                "history_rows": len(hist),
                "spine_rows": n,
                "pit_s": round(t_sys, 4),
                "pit_warm_s": round(t_sys_warm, 4),
                "spine_rows_per_s_warm": int(n / max(t_sys_warm, 1e-9)),
                "naive_python_s": round(t_naive, 4) if t_naive else None,
            }
        )
    return {"table": rows}


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
