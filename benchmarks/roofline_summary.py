"""Roofline summary: renders results/dryrun.json into the §Roofline table.

Per (arch x shape x mesh): the three terms in seconds, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS useful ratio, and peak HBM per device.
This module is pure reporting — the numbers come from the dry-run's
compiled artifacts (see repro/launch/dryrun.py).
"""

from __future__ import annotations

import json
from pathlib import Path

V5E_HBM = 16 * 2**30

#: one-line "what would move the dominant term" note per dominant kind
LEVERS = {
    "compute": (
        "raise useful-FLOP fraction: selective remat policy, drop capacity "
        "padding, fuse small ops"
    ),
    "memory": (
        "cut bytes: chunked/flash attention (no S^2 scores in HBM), fused "
        "norms, bf16 masks"
    ),
    "collective": (
        "cut traffic: sequence-sharded residuals, overlap a2a with expert "
        "FFN, pod-local reductions"
    ),
}


def load(path="results/dryrun.json") -> dict:
    p = Path(path)
    if not p.exists():
        return {}
    return json.loads(p.read_text())


def rows(results: dict, mesh: str = "single") -> list[dict]:
    out = []
    for key, cell in sorted(results.items()):
        if cell.get("skip") or cell.get("error"):
            continue
        if cell.get("mesh") != mesh:
            continue
        r = cell["roofline"]
        peak = cell["memory"]["peak_bytes_per_dev"]
        out.append(
            {
                "arch": cell["arch"],
                "shape": cell["shape"],
                "kind": cell["kind"],
                "compute_ms": round(r["compute_s"] * 1e3, 2),
                "memory_ms": round(r["memory_s"] * 1e3, 2),
                "collective_ms": round(r["collective_s"] * 1e3, 2),
                "dominant": r["dominant"],
                "useful_ratio": (
                    round(r["useful_ratio"], 3) if r.get("useful_ratio") else None
                ),
                "peak_GiB": round(peak / 2**30, 2),
                "fits_v5e": peak <= V5E_HBM,
                "microbatches": cell.get("microbatches", 1),
                "lever": LEVERS[r["dominant"]],
            }
        )
    return out


def summarize(path="results/dryrun.json") -> dict:
    results = load(path)
    single = rows(results, "single")
    multi = rows(results, "multi")
    errors = {
        k: v["error"]
        for k, v in results.items()
        if isinstance(v, dict) and v.get("error")
    }
    skips = [k for k, v in results.items() if isinstance(v, dict) and v.get("skip")]
    return {
        "single_pod": single,
        "multi_pod_compiled": len(multi),
        "errors": errors,
        "skips": skips,
        "cells_single": len(single),
    }


def print_table(path="results/dryrun.json") -> None:
    s = summarize(path)
    hdr = (
        f"{'arch':22s} {'shape':12s} {'cmp_ms':>9s} {'mem_ms':>9s} "
        f"{'col_ms':>9s} {'dom':>10s} {'useful':>7s} {'GiB/dev':>8s} fits µ"
    )
    print(hdr)
    print("-" * len(hdr))
    for r in s["single_pod"]:
        print(
            f"{r['arch']:22s} {r['shape']:12s} {r['compute_ms']:9.1f} "
            f"{r['memory_ms']:9.1f} {r['collective_ms']:9.1f} {r['dominant']:>10s} "
            f"{(r['useful_ratio'] if r['useful_ratio'] is not None else -1):7.3f} "
            f"{r['peak_GiB']:8.2f} {'y' if r['fits_v5e'] else 'N'} {r['microbatches']}"
        )
    print(f"\nmulti-pod cells compiled: {s['multi_pod_compiled']}")
    if s["errors"]:
        print(f"ERRORS: {list(s['errors'])}")
    if s["skips"]:
        print(f"skips (long_500k full-attn): {len(s['skips'])}")


if __name__ == "__main__":
    print_table()
