"""Benchmark: §3.1.6 optimized query execution — DSL vs black-box UDF.

The paper's single explicit performance claim: features declared through the
DSL (rolling-window aggregation being "a common case") can be optimized by
the platform, while UDFs are opaque.  We quantify the three optimization
levels on identical workloads:

  udf-naive     per-agg python/numpy windowing (what a black-box UDF does:
                re-sort, re-scan O(N·W) per aggregation)
  dsl-xla       the DSL plan (shared sort + shared window indices, cumsum
                prefix O(N) per aggregation) on the XLA fallback path
  dsl-kernel    the same plan lowering to the Pallas TPU kernel — CPU runs
                interpret mode, so we report its *analytic* op/byte counts
                (the TPU-roofline estimate), not wall time

Wall times are CPU wall times of the host path; the algorithmic win
(plan sharing + prefix trick) is substrate-independent.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.dsl import DslTransform, RollingAgg
from repro.core.table import Table
from repro.data.sources import SyntheticEventSource

HOUR = 3_600_000


def _workload(n_rows: int, n_aggs: int, seed: int = 0):
    src = SyntheticEventSource(
        "tx", seed=seed, num_entities=max(16, n_rows // 200),
        events_per_bucket=500,
    )
    table = src.read(0, (n_rows // 500 + 1) * HOUR)
    table = table.take(np.arange(min(n_rows, len(table))))
    windows = [2 * HOUR, 6 * HOUR]
    aggs = [
        RollingAgg(f"f{i}", ["amount", "quantity"][i % 2],
                   windows[i % len(windows)], ["sum", "mean"][i % 2])
        for i in range(n_aggs)
    ]
    return table, aggs


def _udf_naive(table: Table, aggs) -> dict[str, np.ndarray]:
    """Black-box UDF baseline: per-agg sort + per-row window scan."""
    out = {}
    for a in aggs:
        order = np.lexsort((table["ts"], table["entity_id"]))
        ent = table["entity_id"][order]
        ts = table["ts"][order]
        val = table[a.source_col][order].astype(np.float64)
        n = len(ent)
        res = np.zeros(n, np.float32)
        start = 0
        for i in range(n):
            if i and ent[i] != ent[i - 1]:
                start = i
            while ts[start] <= ts[i] - a.window or ent[start] != ent[i]:
                start += 1
            w = val[start : i + 1]
            res[i] = w.sum() if a.agg == "sum" else w.mean()
        out[a.output] = res
    return out


def run(sizes=(2_000, 10_000, 50_000), n_aggs=6) -> dict:
    rows = []
    for n in sizes:
        table, aggs = _workload(n, n_aggs)
        ctx = {}

        t0 = time.perf_counter()
        naive = _udf_naive(table, aggs)
        t_naive = time.perf_counter() - t0

        dsl_xla = DslTransform("entity_id", "ts", aggs, use_kernel=False)
        t0 = time.perf_counter()
        out_xla = dsl_xla(table, ctx)
        t_xla = time.perf_counter() - t0
        # repeat with warm jit cache (steady-state number)
        t0 = time.perf_counter()
        out_xla = dsl_xla(table, ctx)
        t_xla_warm = time.perf_counter() - t0

        # correctness cross-check naive vs optimized (both emit rows in
        # (entity, ts) sorted order).  The XLA fallback's global fp32 prefix
        # drifts ~1e-7 * running-total (catastrophic cancellation: ~0.9 abs
        # at 50k rows of ~100-valued events) — the Pallas kernel re-zeroes
        # its prefix per block and does NOT drift (tests/kernels assert
        # tight tolerances); allow the fallback drift here.
        for a in aggs:
            np.testing.assert_allclose(
                out_xla[a.output], naive[a.output], rtol=1e-2, atol=1.0
            )

        # analytic TPU-kernel cost for the shared plan (per distinct window):
        # prefix matmul (H+B)^2·F MACs per block + gather one-hot, vs the
        # UDF's O(N·W·A) reads.
        feat = 2  # distinct source columns
        n_windows = len({a.window for a in aggs})
        kernel_flops = (
            n_windows
            * (len(table) / 256)
            * (512 * 512 * feat * 2 + 256 * 513 * feat * 2)
        )
        naive_reads = sum(
            float(np.sum(np.minimum(np.arange(len(table)) + 1, 200)))  # ~avg span
            for _ in aggs
        )
        rows.append(
            {
                "rows": len(table),
                "aggs": n_aggs,
                "udf_naive_s": round(t_naive, 4),
                "dsl_xla_s": round(t_xla, 4),
                "dsl_xla_warm_s": round(t_xla_warm, 4),
                "speedup_cold": round(t_naive / max(t_xla, 1e-9), 1),
                "speedup_warm": round(t_naive / max(t_xla_warm, 1e-9), 1),
                "kernel_flops_analytic": kernel_flops,
            }
        )
    return {
        "table": rows,
        "notes": (
            "dsl-kernel wall time is interpret-mode on CPU; analytic flops "
            "reported instead"
        ),
    }


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
