"""Benchmark: §4.1.2 geo-replication as MEASURED behavior, not a cost model.

bench_geo contrasts the paper's two access mechanisms with modeled WAN
numbers; this suite exercises the actual data plane built in
core/replication.py:

  * REPLICATION THROUGHPUT — reduced merge batches from a home store's
    100k-row materialization window drained into a replica store, rows/s on
    both sides (home merge vs replica apply) for BOTH planes — online
    winning-writes and offline inserted-chunks — plus per-plane shipped
    bytes and the modeled WAN shipping time, with a byte-identical
    (online) / chunk-set-identical (offline) end-state check.  Since the
    wire transport landed (core/wire.py), the apply timings INCLUDE
    encode->decode, shipped bytes are MEASURED wire frames (raw serialized
    payload and post-zlib wire size, ratio reported), and the WAN model
    prices the compressed size;
  * READ LATENCY — the same feature rows served to a remote consumer via
    cross-region access (home store + WAN penalty) vs a local replica read
    (replica store + local link): measured store wall time + modeled link;
  * FAILOVER — wall time to replay an un-acked two-plane suffix when
    promoting the nearest healthy replica, and the replayed rows/s;
  * CHAOS CONVERGENCE (ISSUE 7) — the same two-plane workload pushed
    through a ``FaultyChannel`` that drops 10% of frames (plus dup /
    reorder / corrupt / ack-loss / latency-spike at lower rates) on a
    seeded deterministic schedule: drain rounds to convergence, the retry
    amplification the at-least-once transport pays, the fault ledger the
    delivery state machine kept, and goodput (unique rows landed per
    wall-second, retries included in the cost).  A partition sub-scenario
    walks one replica HEALTHY -> SUSPECT -> DEAD (driving
    ``topology.mark_down``) and back up via probe recovery.

The throughput and chaos sections run the SAME fixed workloads in --fast
mode: their shipped-byte / retry / fault counts are deterministic
functions of the workload (seeded rng + idempotent merges + seeded fault
schedule over logical drain ticks), which is what lets
benchmarks/check_regression.py gate them EXACTLY against the committed
BENCH_geo_replication.json on every CI run.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.assets import (
    Entity,
    Feature,
    FeatureSetSpec,
    MaterializationSettings,
)
from repro.core.dsl import UDFTransform
from repro.core.offline_store import CREATION_TS, OfflineStore
from repro.core.online_store import OnlineStore
from repro.core import wire
from repro.core.channel import FaultPlan, FaultyChannel
from repro.core.daemon import SocketChannel, spawn_replica_daemon
from repro.core.regions import GeoTopology, Region
from repro.core.replication import DeliveryPolicy, GeoReplicator, ReplicationLog
from repro.core.table import Table

REGIONS = ("westus2", "eastus", "westeurope")


def _topo() -> GeoTopology:
    return GeoTopology(
        regions={r: Region(r) for r in REGIONS},
        local_latency_ms=1.0,
        cross_region_latency_ms=60.0,
        link_latency_ms={
            ("westus2", "eastus"): 32.0,
            ("westus2", "westeurope"): 70.0,
            ("eastus", "westeurope"): 40.0,
        },
        cross_region_gbps=1.0,
    )


def _spec(n_feats: int = 2) -> FeatureSetSpec:
    return FeatureSetSpec(
        name="geo",
        version=1,
        entity=Entity("customer", ("entity_id",)),
        features=tuple(Feature(f"f{i}") for i in range(n_feats)),
        source_name="direct",
        transform=UDFTransform(lambda df, ctx: df, name="id"),
        timestamp_col="ts",
        materialization=MaterializationSettings(True, True),
    )


def _frame(rng, n: int, entities: int, t0: int, n_feats: int = 2) -> Table:
    cols = {
        "entity_id": rng.integers(0, entities, n).astype(np.int64),
        "ts": (t0 + rng.integers(0, 10**6, n)).astype(np.int64),
    }
    for i in range(n_feats):
        cols[f"f{i}"] = rng.random(n).astype(np.float32)
    return Table(cols)


def _assert_identical(a: OnlineStore, b: OnlineStore, spec) -> None:
    da = a.dump_all(spec.name, spec.version)
    db = b.dump_all(spec.name, spec.version)
    for name in da.names:
        np.testing.assert_array_equal(da[name], db[name], err_msg=name)


def _assert_offline_identical(a: OfflineStore, b: OfflineStore, spec) -> None:
    da = a.canonical_history(spec.name, spec.version)
    db = b.canonical_history(spec.name, spec.version)
    assert len(da) == len(db), f"offline rows {len(da)} vs {len(db)}"
    for name in da.names:
        np.testing.assert_array_equal(da[name], db[name], err_msg=name)


def bench_replication_throughput(
    window_rows: int = 100_000, batches: int = 10, entities: int = 50_000
) -> dict:
    """Merge one materialization window into the home stores batch by
    batch, then drain the log into a replica: rows/s on each side of the
    WAN, one timed phase per plane so the numbers don't blend."""
    spec = _spec()
    topo = _topo()
    home = OnlineStore()
    home_off = OfflineStore()
    log = ReplicationLog(capacity=8 * batches)
    repl = GeoReplicator(
        home, topology=topo, home_region="westus2", home_offline=home_off, log=log
    )
    replica = OnlineStore()
    replica_off = OfflineStore()
    repl.add_replica("eastus", replica, replica_off)

    rng = np.random.default_rng(7)
    per_batch = window_rows // batches
    frames = [
        _frame(rng, per_batch, entities, 10**6 * (i + 1)) for i in range(batches)
    ]
    # warm: seed every id so the timed window runs the steady-state
    # override/no-op path on both sides, capacity growth off the clock
    warm = Table(
        {
            "entity_id": np.arange(entities, dtype=np.int64),
            "ts": np.zeros(entities, np.int64),
            "f0": np.zeros(entities, np.float32),
            "f1": np.zeros(entities, np.float32),
        }
    )
    home.merge(spec, warm, 10**6)
    home_off.merge(spec, warm, 10**6)
    repl.drain()

    # -- online plane: merge at home, then drain the log into the replica
    t0 = time.perf_counter()
    for i, f in enumerate(frames):
        home.merge(spec, f, 10**8 + i)
    home_wall = time.perf_counter() - t0
    pending = log.lag("eastus")
    t0 = time.perf_counter()
    repl.drain("eastus")
    apply_wall = time.perf_counter() - t0
    _assert_identical(home, replica, spec)

    # -- offline plane: same frames, insert-if-absent history merges
    t0 = time.perf_counter()
    for i, f in enumerate(frames):
        home_off.merge(spec, f, 2 * 10**8 + i)
    off_home_wall = time.perf_counter() - t0
    off_pending = log.lag("eastus")
    t0 = time.perf_counter()
    repl.drain("eastus")
    off_apply_wall = time.perf_counter() - t0
    _assert_offline_identical(home_off, replica_off, spec)

    ship = repl.shipped["eastus"]
    by_plane = ship.by_plane
    return {
        "window_rows": window_rows,
        "batches": batches,
        "home_merge_rows_per_s": int(window_rows / home_wall),
        "shipped_rows": pending.rows,
        "reduction_x": round(window_rows / max(pending.rows, 1), 2),
        "replica_apply_rows_per_s": int(pending.rows / apply_wall),
        "window_rows_per_s_through_replication": int(window_rows / apply_wall),
        # measured wire traffic, per plane: raw = serialized payload bytes,
        # (plain) bytes = post-zlib frame bytes actually priced by the WAN
        "shipped_bytes": by_plane["online"].bytes,
        "shipped_raw_bytes": by_plane["online"].raw_bytes,
        "home_offline_merge_rows_per_s": int(window_rows / off_home_wall),
        "offline_shipped_rows": off_pending.rows,
        "offline_apply_rows_per_s": int(off_pending.rows / off_apply_wall),
        "offline_shipped_bytes": by_plane["offline"].bytes,
        "offline_shipped_raw_bytes": by_plane["offline"].raw_bytes,
        "wire_frames": ship.frames,
        # header-aware, matching WireFrame.compression_ratio: exactly 1.0 at
        # break-even raw shipping, so the CI gate's >= 1.0 floor is sound
        # even for an uncompressed (compress_level=0) re-baseline
        "compression_ratio": round(
            (ship.raw_bytes + wire.HEADER_SIZE * ship.frames)
            / max(ship.bytes, 1),
            3,
        ),
        "modeled_wan_ship_ms": round(ship.ms, 2),
        "replica_state_identical": True,
        "offline_state_identical": True,
    }


def bench_read_latency(
    entities: int = 20_000, batch: int = 256, rounds: int = 30
) -> dict:
    """One consumer in eastus, data homed in westus2: measured store wall
    time + modeled link latency for the two §4.1.2 mechanisms."""
    spec = _spec()
    topo = _topo()
    home = OnlineStore()
    log = ReplicationLog()
    repl = GeoReplicator(home, topology=topo, home_region="westus2", log=log)
    replica = OnlineStore()
    repl.add_replica("eastus", replica)

    rng = np.random.default_rng(11)
    home.merge(spec, _frame(rng, entities * 2, entities, 0), 10**6)
    repl.drain()
    _assert_identical(home, replica, spec)

    def timed_gets(store: OnlineStore) -> float:
        ids = [rng.integers(0, entities, batch).astype(np.int64)]
        store.lookup("geo", 1, ids, use_kernel=False)  # warm
        lat = []
        for _ in range(rounds):
            ids = [rng.integers(0, entities, batch).astype(np.int64)]
            t0 = time.perf_counter()
            store.lookup("geo", 1, ids, use_kernel=False)
            lat.append((time.perf_counter() - t0) * 1e3)
        return float(np.percentile(lat, 50))

    home_ms = timed_gets(home)
    replica_ms = timed_gets(replica)
    wan = topo.latency("eastus", "westus2")
    local = topo.latency("eastus", "eastus")
    cross = home_ms + wan
    repl_total = replica_ms + local
    return {
        "batch": batch,
        "store_ms_p50": {"home": round(home_ms, 3), "replica": round(replica_ms, 3)},
        "cross_region_total_ms": round(cross, 3),
        "geo_replicated_total_ms": round(repl_total, 3),
        "local_read_speedup_x": round(cross / repl_total, 1),
    }


def bench_failover_replay(
    entities: int = 20_000, suffix_rows: int = 50_000, batches: int = 5
) -> dict:
    """Un-acked suffix replay: the data-plane cost of promoting a replica —
    the suffix carries BOTH planes, and the promoted region ends with the
    lost home's online bytes and offline chunk set."""
    spec = _spec()
    topo = _topo()
    home = OnlineStore()
    home_off = OfflineStore()
    log = ReplicationLog()
    repl = GeoReplicator(
        home, topology=topo, home_region="westus2", home_offline=home_off, log=log
    )
    east_off = OfflineStore()
    repl.add_replica("eastus", OnlineStore(), east_off)
    repl.add_replica("westeurope", OnlineStore(), OfflineStore())

    rng = np.random.default_rng(13)
    base = _frame(rng, entities * 2, entities, 0)
    home.merge(spec, base, 10**6)
    home_off.merge(spec, base, 10**6)
    repl.drain()
    per_batch = suffix_rows // batches
    for i in range(batches):  # the suffix no replica has applied yet
        f = _frame(rng, per_batch, entities, 10**6 * (i + 2))
        home.merge(spec, f, 10**8 + i)
        home_off.merge(spec, f, 10**8 + i)
    pre_failure = home.dump_all("geo", 1)
    pre_failure_off_rows = home_off.num_rows("geo", 1)
    lag = repl.lag("eastus")

    topo.regions["westus2"].healthy = False
    t0 = time.perf_counter()
    promoted = repl.promote("eastus")
    wall = time.perf_counter() - t0
    post = repl.stores["eastus"].dump_all("geo", 1)
    for name in post.names:
        np.testing.assert_array_equal(post[name], pre_failure[name], err_msg=name)
    assert east_off.num_rows("geo", 1) == pre_failure_off_rows
    return {
        "unacked_batches": lag.batches,
        "unacked_rows": lag.rows,
        "unacked_offline_rows": lag.offline.rows,
        "replay_ms": round(wall * 1e3, 2),
        "replay_rows_per_s": int(promoted["replayed_rows"] / max(wall, 1e-9)),
        "promoted_state_identical": True,
    }


CHAOS_RATES = {
    "drop": 0.10,
    "dup": 0.05,
    "reorder": 0.05,
    "corrupt": 0.05,
    "ack_loss": 0.03,
    "spike": 0.02,
}


def _chaos_partition() -> dict:
    """Partition sub-scenario: one replica behind a transmit-event window
    that eats everything (frames AND probes).  The delivery state machine
    must walk HEALTHY -> SUSPECT -> DEAD, drive ``topology.mark_down``,
    keep probing on its schedule, and recover + converge once the window
    passes — all on logical drain ticks, so every field is deterministic."""
    spec = _spec()
    topo = _topo()
    channel = FaultyChannel(
        FaultPlan(seed=11, partitions=(("eastus", 0, 10),)), topo
    )
    policy = DeliveryPolicy(
        suspect_after=2, dead_after=4, backoff_base=1, backoff_cap=2,
        probe_interval=1,
    )
    home = OnlineStore()
    log = ReplicationLog()
    repl = GeoReplicator(
        home, topology=topo, home_region="westus2", log=log,
        channel=channel, policy=policy,
    )
    replica = OnlineStore()
    repl.add_replica("eastus", replica)

    rng = np.random.default_rng(17)
    home.merge(spec, _frame(rng, 2_000, 1_000, 10**6), 10**8)
    st = repl.delivery["eastus"]
    dead_at_round = None
    marked_down_at_dead = False
    rounds = 0
    while log.pending_count("eastus") > 0:
        rounds += 1
        if rounds > 200:
            raise RuntimeError("partition scenario did not converge")
        repl.drain("eastus")
        if dead_at_round is None and st.status == "dead":
            dead_at_round = rounds
            marked_down_at_dead = not topo.regions["eastus"].healthy
    _assert_identical(home, replica, spec)
    return {
        "partition_events": 10,
        "rounds_to_converge": rounds,
        "dead_at_round": dead_at_round,
        "detection_marked_region_down": marked_down_at_dead,
        "probes": st.probes,
        "timeouts": st.timeouts,
        "transitions": [f"{a}->{b}" for _, a, b in st.transitions],
        "recovered": st.status == "healthy" and topo.regions["eastus"].healthy,
        "converged_identical": True,
    }


def bench_chaos_convergence(
    window_rows: int = 20_000, batches: int = 10, entities: int = 10_000
) -> dict:
    """Two-plane replication through a lossy WAN: 10% frame drop plus
    lower-rate duplicate / reorder / corrupt / ack-loss / spike faults on a
    seeded schedule.  Drains until the replica's cursor reaches the head,
    then verifies both planes byte-identical — convergence is ASSERTED, not
    assumed.  Every count here (rounds, retries, timeouts, fault ledger,
    channel injections) is a pure function of (workload seed, fault seed,
    logical drain ticks), so check_regression.py gates them EXACTLY; only
    ``goodput_rows_per_s`` is wall-clock (gated within tolerance)."""
    spec = _spec()
    topo = _topo()
    # seed 8 strikes every fault kind at least once within the run's
    # transmit-event horizon, so each ledger counter gets a nonzero gate
    plan = FaultPlan(
        seed=8,
        drop_rate=CHAOS_RATES["drop"],
        dup_rate=CHAOS_RATES["dup"],
        reorder_rate=CHAOS_RATES["reorder"],
        corrupt_rate=CHAOS_RATES["corrupt"],
        ack_loss_rate=CHAOS_RATES["ack_loss"],
        spike_rate=CHAOS_RATES["spike"],
    )
    channel = FaultyChannel(plan, topo)
    # small backoff cap so convergence doesn't idle through deferred ticks
    policy = DeliveryPolicy(
        suspect_after=2, dead_after=5, backoff_base=1, backoff_cap=4,
        probe_interval=2,
    )
    home = OnlineStore()
    home_off = OfflineStore()
    log = ReplicationLog(capacity=8 * batches)
    repl = GeoReplicator(
        home, topology=topo, home_region="westus2", home_offline=home_off,
        log=log, channel=channel, policy=policy,
    )
    replica = OnlineStore()
    replica_off = OfflineStore()
    repl.add_replica("eastus", replica, replica_off)

    rng = np.random.default_rng(7)
    per_batch = window_rows // batches
    for i in range(batches):
        f = _frame(rng, per_batch, entities, 10**6 * (i + 1))
        home.merge(spec, f, 10**8 + i)
        home_off.merge(spec, f, 2 * 10**8 + i)
    pending = log.lag("eastus")

    rounds = 0
    t0 = time.perf_counter()
    while log.pending_count("eastus") > 0:
        rounds += 1
        if rounds > 400:
            raise RuntimeError("chaos workload did not converge in 400 rounds")
        repl.drain("eastus")
    wall = time.perf_counter() - t0
    _assert_identical(home, replica, spec)
    _assert_offline_identical(home_off, replica_off, spec)

    st = repl.delivery["eastus"]
    ship = repl.shipped["eastus"]
    unique_batches = pending.batches
    return {
        "seed": plan.seed,
        "fault_rates": dict(CHAOS_RATES),
        "window_rows": window_rows,
        "unique_rows": pending.rows,
        "unique_batches": unique_batches,
        "drain_rounds": rounds,
        "retried_batches": st.retries,
        "timeouts": st.timeouts,
        "corrupt_frames": st.corrupt_frames,
        "redelivered_batches": st.redelivered_batches,
        "channel_counts": dict(channel.counts),
        "applied_batches": ship.batches,
        # at-least-once redundancy cost: batches applied (incl. redeliveries)
        # per unique logged batch, and wire bytes per unique payload byte
        "retry_amplification_x": round(
            ship.batches / max(unique_batches, 1), 3
        ),
        "shipped_bytes": ship.bytes,
        "goodput_rows_per_s": int(pending.rows / max(wall, 1e-9)),
        "converged_identical": True,
        "partition": _chaos_partition(),
    }


def _ship_over_socket(
    window: int, rtt_ms: float, batches: int, per_batch: int, entities: int
) -> dict:
    """One real-socket shipping run: spawn a replica daemon, publish the
    seeded two-plane window (interleaved planes, so the coalesced runs
    stay single-batch and the in-flight window has real work), drain with
    the given ``inflight_window``, and verify the daemon's state against
    home through its dump stream."""
    spec = _spec()
    topo = _topo()
    home = OnlineStore()
    home_off = OfflineStore()
    log = ReplicationLog(capacity=8 * batches)
    repl = GeoReplicator(
        home,
        topology=topo,
        home_region="westus2",
        home_offline=home_off,
        log=log,
        policy=DeliveryPolicy(inflight_window=window),
    )
    rng = np.random.default_rng(7)
    with spawn_replica_daemon(region="eastus") as handle:
        ch = SocketChannel(
            handle.connect(),
            src="westus2",
            dst="eastus",
            topology=topo,
            min_rtt_ms=rtt_ms,
        )
        repl.add_remote_replica("eastus", ch, offline=True)
        for i in range(batches):
            f = _frame(rng, per_batch, entities, 10**6 * (i + 1))
            home.merge(spec, f, 10**8 + i)
            home_off.merge(spec, f, 2 * 10**8 + i)
        t0 = time.perf_counter()
        repl.drain("eastus")
        wall = time.perf_counter() - t0
        assert log.pending_count("eastus") == 0, "socket drain did not converge"

        # convergence read through the daemon's own dump stream
        adopted = OnlineStore()
        adopted.register(spec)
        for b in ch.fetch_dump(spec, "online"):
            adopted.merge_reduced(spec, b.keys, b.event_ts, b.values, b.creation_ts)
        _assert_identical(home, adopted, spec)
        adopted_off = OfflineStore()
        adopted_off.register(spec)
        for b in ch.fetch_dump(spec, "offline"):
            cols = dict(b.columns or {})
            creation = cols.pop(CREATION_TS, b.creation_ts)
            adopted_off.apply_chunks(spec, b.keys, b.event_ts, creation, cols)
        _assert_offline_identical(home_off, adopted_off, spec)

        ledger = ch.ledger()
        ship = repl.shipped["eastus"]
        st = repl.delivery["eastus"]
        out = {
            "ship_ms": round(wall * 1e3, 2),
            "frames": ledger["frames"],
            "batches_applied": ledger["batches_applied"],
            "rows_applied": ledger["rows_applied"],
            "nacks": ledger["nacks"],
            "timeouts": st.timeouts,
            "shipped_bytes": ship.bytes,
            "shipped_raw_bytes": ship.raw_bytes,
            "measured_rtt_ms": round(
                topo.measured_latency("westus2", "eastus") or 0.0, 2
            ),
        }
        ch.close()
    return out


def bench_socket_transport(
    window_rows: int = 100_000,
    batches: int = 20,
    entities: int = 50_000,
    rtt_ms: float = 20.0,
    inflight_window: int = 8,
) -> dict:
    """Real-socket transport phase (ISSUE 8): the 100k-row two-plane
    window shipped into a child replica daemon over a localhost socket,
    once serialized (``inflight_window=1``: one frame on the wire, wait
    the full emulated round-trip, repeat) and once pipelined (window=8:
    the link stays full while acks mature).  The emulated ``rtt_ms`` is
    the netem-style delay a WAN deployment would pay per round-trip —
    localhost acks return in microseconds, which would hide exactly the
    stall the window exists to absorb.  Both runs replicate the identical
    seeded workload, both are verified byte-identical (online) /
    chunk-set-identical (offline) against the daemon's dump stream, and
    their shipped wire bytes must agree with each other exactly (the
    pipelining is a scheduling change, not a format change)."""
    per_batch = window_rows // batches
    serial = _ship_over_socket(1, rtt_ms, batches, per_batch, entities)
    pipelined = _ship_over_socket(
        inflight_window, rtt_ms, batches, per_batch, entities
    )
    assert serial["shipped_bytes"] == pipelined["shipped_bytes"], (
        "pipelined run shipped different wire bytes than serialized: "
        f"{pipelined['shipped_bytes']} vs {serial['shipped_bytes']}"
    )
    return {
        "window_rows": window_rows,
        "batches": batches,
        "emulated_rtt_ms": rtt_ms,
        "inflight_window": inflight_window,
        "wire_frames": serial["frames"],
        "shipped_bytes": serial["shipped_bytes"],
        "shipped_raw_bytes": serial["shipped_raw_bytes"],
        "serialized": serial,
        "pipelined": pipelined,
        "pipeline_speedup_x": round(
            serial["ship_ms"] / max(pipelined["ship_ms"], 1e-9), 2
        ),
        "socket_state_identical": True,
        "socket_offline_state_identical": True,
    }


def bench_multi_home(batches: int = 8, rows: int = 2_000) -> dict:
    """Active-active multi-home mesh (core/multihome.py): every region is a
    write home for its hash range of the keyspace.  The workload is fully
    deterministic (seeded rng, fixed ShardMap, idempotent merges), so the
    per-shard shipped bytes and the convergence booleans gate EXACTLY
    against the committed artifact; the forwarded-write fraction is a pure
    function of the key hash and gates within the calibrated tolerance.

    Three sub-drills ride the same store: (1) concurrent writes entering
    at all three regions, drained to convergence; (2) per-shard failover —
    one region dies with un-drained batches, ONLY its range promotes; (3)
    the dead region rejoins (per-home owned-range delta bootstrap) and a
    rebalance hands it a range back, after which writes entering at the
    rejoined region converge again."""
    from repro.core.multihome import MultiHomeGeoStore

    rng = np.random.default_rng(17)
    topo = _topo()
    spec = _spec()
    mh = MultiHomeGeoStore(
        "bench-mh", topology=topo, regions=list(REGIONS), online_partitions=8
    )
    mh.create_feature_set(spec)
    mh.advance_clock(3 * 10**8)

    # -- concurrent writes at every region -------------------------------
    t0 = time.perf_counter()
    for i in range(batches):
        for region in REGIONS:
            mh.write_batch(
                "geo",
                1,
                _frame(rng, rows, 5_000, 10**6 * i),
                creation_ts=2 * 10**8 + i,
                region=region,
            )
    write_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    converge_rounds = mh.converge()
    drain_wall = time.perf_counter() - t0

    def _mesh_identical() -> tuple[bool, bool]:
        regions = list(mh.replicators)
        ref_on = mh.online[regions[0]].dump_all("geo", 1)
        online_ok = True
        for r in regions[1:]:
            d = mh.online[r].dump_all("geo", 1)
            online_ok &= set(d.names) == set(ref_on.names) and all(
                np.array_equal(ref_on[n], d[n]) for n in ref_on.names
            )
        ref_off = mh.offline[regions[0]].canonical_history("geo", 1)
        offline_ok = True
        for r in regions[1:]:
            h = mh.offline[r].canonical_history("geo", 1)
            offline_ok &= set(h.names) == set(ref_off.names) and all(
                np.array_equal(ref_off[n], h[n]) for n in ref_off.names
            )
        return online_ok, offline_ok

    online_ok, offline_ok = _mesh_identical()
    # one home = one shard here, so per-home-log ledgers ARE per-shard
    # shipped bytes: sum each home's wire bytes over its replica links
    per_shard_bytes = {
        str(sid): sum(
            ledger.bytes
            for ledger in mh.replicators[
                mh.shard_map.owner_of(sid)
            ].shipped.values()
        )
        for sid in range(mh.shard_map.num_shards)
    }
    total_rows = mh.write_log["rows"]
    forwarded = mh.write_log["forwarded_rows"]

    # -- per-shard failover: one region dies with un-drained batches -----
    victim = REGIONS[2]
    for region in REGIONS:
        mh.write_batch(
            "geo",
            1,
            _frame(rng, rows, 5_000, 10**6 * batches),
            creation_ts=2 * 10**8 + batches,
            region=region,
        )
    lost_shards = list(mh.shard_map.owned_shards(victim))
    mh.mark_down(victim)
    t0 = time.perf_counter()
    fo = mh.failover(victim)
    failover_wall = time.perf_counter() - t0
    mh.converge()
    fo_online_ok, fo_offline_ok = _mesh_identical()

    # -- rejoin + rebalance: the range moves back to the recovered region -
    mh.mark_up(victim)
    rj = mh.rejoin(victim)
    moved = mh.rebalance(lost_shards[0], victim)
    for region in (victim, REGIONS[0]):
        mh.write_batch(
            "geo",
            1,
            _frame(rng, rows, 5_000, 10**6 * (batches + 1)),
            creation_ts=2 * 10**8 + batches + 1,
            region=region,
        )
    mh.converge()
    rb_online_ok, rb_offline_ok = _mesh_identical()

    return {
        "regions": len(REGIONS),
        "num_shards": mh.shard_map.num_shards,
        "write_rows": total_rows,
        "forwarded_rows": forwarded,
        "forwarded_fraction": round(forwarded / max(total_rows, 1), 4),
        "multi_home_write_rows_per_s": int(batches * rows * len(REGIONS) / write_wall),
        "converge_rounds": converge_rounds,
        "drain_rows_per_s": int(total_rows / max(drain_wall, 1e-9)),
        "per_shard_shipped_bytes": per_shard_bytes,
        "online_identical": online_ok,
        "offline_identical": offline_ok,
        "failover": {
            "victim": victim,
            "promoted": fo["promoted"],
            "shards_moved": fo["shards"],
            "replayed_rows": fo["replayed_rows"],
            "failover_ms": round(failover_wall * 1e3, 2),
            "online_identical": fo_online_ok,
            "offline_identical": fo_offline_ok,
        },
        "rejoin_rebalance": {
            "bootstrap_online_rows": rj["online_rows"],
            "bootstrap_offline_rows": rj["offline_rows"],
            "moved_shard": moved["shard"],
            "online_identical": rb_online_ok,
            "offline_identical": rb_offline_ok,
        },
    }


def run(fast: bool = False) -> dict:
    # throughput and chaos keep their full deterministic workloads even in
    # --fast (both are sub-second): check_regression.py gates their
    # shipped-byte / retry / fault counts EXACTLY against the committed
    # artifact, so the shapes must match the baseline
    return {
        "throughput": bench_replication_throughput(),
        "read_latency": bench_read_latency(rounds=10 if fast else 30),
        "failover": bench_failover_replay(suffix_rows=10_000 if fast else 50_000),
        "chaos": bench_chaos_convergence(),
        # the socket phase keeps its full workload in --fast too: its byte
        # counts and convergence booleans are gated like the rest
        "socket": bench_socket_transport(),
        # multi-home keeps its full deterministic workload as well: per-
        # shard shipped bytes and convergence booleans gate exactly
        "multi_home": bench_multi_home(),
    }


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
