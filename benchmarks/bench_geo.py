"""Benchmark: §4.1.2 cross-region access vs geo-replication (Fig. 4).

Contrasts the paper's two mechanisms with the topology's latency model
(local vs WAN tiers) across read mixes, plus straggler mitigation
(speculative re-execution) for sharded materialization — the §3.1.2
"resources from cross regions" story with measurable numbers.
"""

from __future__ import annotations

import numpy as np

from repro.core.regions import (
    GeoPlacement,
    GeoTopology,
    Region,
    ReplicationPolicy,
)
from repro.runtime.supervisor import SpeculativeExecutor, WorkerPool


def run(n_reads=10_000, consumer_mix=(0.4, 0.4, 0.2)) -> dict:
    regions = ["westus2", "eastus", "westeurope"]
    rng = np.random.default_rng(0)
    consumers = rng.choice(regions, size=n_reads, p=consumer_mix)

    def simulate(policy, replicas):
        topo = GeoTopology(
            {r: Region(r) for r in regions},
            local_latency_ms=1.0, cross_region_latency_ms=60.0,
        )
        geo = GeoPlacement(topo, "westus2", policy)
        for r in replicas:
            geo.add_replica(r)
        ms = np.array([geo.route_read(c)[1] for c in consumers])
        return {
            "mean_ms": round(float(ms.mean()), 2),
            "p99_ms": round(float(np.percentile(ms, 99)), 2),
            "local_fraction": round(float((ms <= 1.0).mean()), 3),
        }

    cross = simulate(ReplicationPolicy.CROSS_REGION_ACCESS, [])
    repl = simulate(ReplicationPolicy.GEO_REPLICATED, ["eastus", "westeurope"])

    # -- straggler mitigation --------------------------------------------------
    pool = WorkerPool({"w0": 1.0, "w1": 1.0, "w2": 1.0, "w3": 6.0})  # one slow
    executor = SpeculativeExecutor(pool, deadline_factor=2.0)
    shards = list(range(32))
    done = executor.run_shards(shards, lambda s: s * s, shard_cost=0.001)
    assert done == {s: s * s for s in shards}

    return {
        "cross_region_access": cross,
        "geo_replicated": repl,
        "replication_speedup_mean": round(cross["mean_ms"] / repl["mean_ms"], 1),
        "straggler": {
            "shards": len(shards),
            "speculated": len(executor.speculated),
            "all_results_correct": True,
        },
    }


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
